"""Tensor-parallel sharded replicas (parallel/tp.py + launch plumbing).

The battery pins the TP contract end to end:

(1) *plan law* — ``plan_tp`` never errors on ragged head/ff counts: an
    indivisible component degrades to replication with the reason recorded,
    and ``local_config`` divides exactly what the plan sharded (property
    tests drive arbitrary head/kv/ff combinations through the fallback);
(2) *operand slicing is exact* — ``simulator.shard_operands`` slices stored
    bit planes such that densify∘shard == shard∘densify byte-for-byte, and
    dense leaves concatenate back to the global tensor;
(3) *serving parity* — ``tp_generate`` token streams match solo
    single-device ``serve.generate`` at shard counts {1, 2, 4} for dense,
    packed/raw and packed/col_perm materializations (bit-identical at n=1:
    psum over a 1-shard axis is the identity), and ``Engine(tp=...)`` holds
    the same parity through ragged mixed-sampling traffic and swap
    preemption;
(4) *pool partition* — ``build_sharded_deployment`` reproduces the global
    deployment bit-exactly (same per-tensor PRNG schedule) and, under
    per-tensor pristine accounting, the summed wear of the shard pools
    equals the unsharded pool's wear exactly (conservation);
(5) *scrub under sharding* — ``ShardedScrub`` repairs a deterministic storm
    across per-shard pools between engine dispatches without stalling the
    replica, and post-refresh tokens match the clean deployment;
(6) *mesh carve-up* — ``replica_submeshes`` groups are contiguous on the
    model axis, warn-and-emulate on one device, and reject non-contiguous
    wrap-around.

The native ``shard_map`` path (real N-device mesh) is pinned by a
subprocess test under ``--xla_force_host_platform_device_count=4`` (marked
slow; the multi-device CI job also runs the in-process ``skipif``-gated
variant) together with the ``sws.stable_argsort`` routing regression:
emulated devices must not flip the host-callback guard.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import get_arch
from repro.core import simulator
from repro.core.integrity import IntegrityConfig
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    build_deployment,
    deploy_params,
)
from repro.core.pool import CrossbarPool
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.fleet import Fleet, FleetConfig
from repro.launch.mesh import replica_submeshes
from repro.launch.serve import generate
from repro.models import api
from repro.parallel import tp
from repro.parallel.tp import (
    ShardedScrub,
    build_sharded_deployment,
    local_config,
    plan_tp,
    shard_params,
    tp_generate,
)

ECFG = EngineConfig(
    max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8, decode_quantum=4
)
LM_SPEC = CrossbarSpec(rows=128, cols=10)
LM_CFG = PlannerConfig(p_stuck=0.5, min_size=1024)


@pytest.fixture(scope="module")
def lm():
    """internlm2 reduced: 4 heads / 2 KV heads / d_ff=128 — shardable at 2,
    attention-fallback (kv 2 % 4) at 4."""
    cfg = get_arch("internlm2-1.8b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_requests(cfg, specs, rid0=0, greedy=True):
    out = []
    for i, (plen, gen) in enumerate(specs):
        rid = rid0 + i
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
        )
        out.append(Request(rid=rid, prompt=prompt, max_new_tokens=gen,
                           greedy=greedy, seed=rid))
    return out


def _solo(cfg, params, req):
    batch = {"tokens": jnp.asarray(req.prompt)[None]}
    toks, _ = generate(cfg, params, batch, gen_len=req.max_new_tokens,
                       greedy=req.greedy, seed=req.seed)
    return [int(t) for t in np.asarray(toks[0])]


# ---------------------------------------------------------------------------
# (6) mesh carve-up
# ---------------------------------------------------------------------------

def test_replica_submeshes_contiguous_groups(monkeypatch):
    fake = [object() for _ in range(4)]
    monkeypatch.setattr(jax, "devices", lambda: list(fake))
    assert replica_submeshes(2, 2) == [[fake[0], fake[1]], [fake[2], fake[3]]]
    assert replica_submeshes(1, 4) == [fake]
    # spr == 1 wraps silently over the available devices (PR 8 behavior)
    assert replica_submeshes(6, 1) == [[fake[i % 4]] for i in range(6)]
    # a full lap is fine: replica 2 restarts at device 0, still contiguous
    assert replica_submeshes(3, 2)[2] == [fake[0], fake[1]]


def test_replica_submeshes_rejects_noncontiguous_wrap(monkeypatch):
    fake = [object() for _ in range(4)]
    monkeypatch.setattr(jax, "devices", lambda: list(fake))
    # replica 1 would start at device 3 and need devices {3, 0, 1}
    with pytest.raises(ValueError, match="non-contiguously"):
        replica_submeshes(2, 3)


def test_replica_submeshes_single_device_emulates_with_warning():
    assert len(jax.devices()) == 1  # the tier-1 contract the module relies on
    with pytest.warns(UserWarning, match="vmap-emulated"):
        groups = replica_submeshes(2, 4)
    assert groups == [[jax.devices()[0]] * 4] * 2


def test_replica_submeshes_validation():
    with pytest.raises(ValueError):
        replica_submeshes(0, 1)
    with pytest.raises(ValueError):
        replica_submeshes(1, 0)


# ---------------------------------------------------------------------------
# (1) plan law
# ---------------------------------------------------------------------------

def test_plan_tp_shards_both_components(lm):
    cfg, _ = lm
    plan = plan_tp(cfg, 2, packed=True)
    assert plan.attn and plan.mlp
    assert plan.rules["attn/wq"] == -1 and plan.rules["attn/wo"] == -2
    assert plan.rules["mlp/wi_gate"] == -1 and plan.rules["mlp/wo"] == -2
    loc = local_config(cfg, plan)
    assert (loc.n_heads, loc.n_kv_heads, loc.d_ff) == (2, 1, 64)
    assert loc.resolved_head_dim == cfg.resolved_head_dim  # pinned, not re-derived
    assert loc.tp_attn and loc.tp_mlp and loc.tp_axis == plan.axis


def test_plan_tp_attention_fallback_keeps_mlp(lm):
    cfg, _ = lm
    plan = plan_tp(cfg, 4, packed=True)
    assert not plan.attn and plan.mlp
    assert "n_kv_heads 2 % 4" in plan.reasons["attn"]
    loc = local_config(cfg, plan)
    assert loc.n_heads == cfg.n_heads and loc.d_ff == 32
    assert not loc.tp_attn and loc.tp_mlp


def test_plan_tp_mqa_replicates_attention():
    cfg = get_arch("gemma-2b", reduced=True)  # MQA: one KV head
    plan = plan_tp(cfg, 2)
    assert not plan.attn and "n_kv_heads 1 % 2" in plan.reasons["attn"]


def test_plan_tp_foreign_block_kinds_replicate_everything():
    cfg = get_arch("xlstm-350m", reduced=True)
    plan = plan_tp(cfg, 2)
    assert not plan.attn and not plan.mlp and not plan.rules
    assert "no TP reduction gates" in plan.reasons["attn"]


def test_plan_tp_packed_byte_alignment_gate(lm):
    cfg, _ = lm
    # head_dim 16: dense 2-way slice of wo's K axis is 32 rows (byte-aligned),
    # but head_dim 4 would make it 8... shrink to force the packed-only gate:
    ragged = dataclasses.replace(cfg, head_dim=1)
    assert plan_tp(ragged, 2, packed=False).attn
    plan = plan_tp(ragged, 2, packed=True)
    assert not plan.attn and "byte-aligned" in plan.reasons["attn"]


@given(
    n_heads=st.sampled_from([1, 2, 3, 4, 6, 8]),
    kv_div=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([4, 8, 16]),
    d_ff=st.sampled_from([24, 32, 48, 64, 120, 128]),
    n=st.integers(min_value=1, max_value=5),
    packed=st.booleans(),
)
def test_plan_tp_fallback_law(n_heads, kv_div, head_dim, d_ff, n, packed):
    """Any head/kv/ff combination plans without error; sharded components
    divide exactly and replicated ones record why."""
    if n_heads % kv_div:
        kv_div = 1
    base = get_arch("internlm2-1.8b", reduced=True)
    cfg = dataclasses.replace(
        base, n_heads=n_heads, n_kv_heads=n_heads // kv_div,
        head_dim=head_dim, d_ff=d_ff,
    )
    plan = plan_tp(cfg, n, packed=packed)
    loc = local_config(cfg, plan)
    if plan.attn:
        assert cfg.n_heads % n == 0 and cfg.n_kv_heads % n == 0
        assert loc.n_heads * n == cfg.n_heads
        assert loc.n_kv_heads * n == cfg.n_kv_heads
        if packed:
            assert (loc.n_heads * head_dim) % 8 == 0
    elif n > 1:
        assert "attn" in plan.reasons
    if plan.mlp:
        assert loc.d_ff * n == cfg.d_ff
        if packed:
            assert loc.d_ff % 8 == 0
    elif n > 1:
        assert "mlp" in plan.reasons


# ---------------------------------------------------------------------------
# (2) operand slicing exactness
# ---------------------------------------------------------------------------

def _rand_operands(key, k, n_cols, codec="raw"):
    w = jax.random.normal(key, (k, n_cols)) * 0.05
    scale = float(jnp.max(jnp.abs(w))) / (2**4 - 1)
    q = jnp.clip(jnp.round(jnp.abs(w) / scale), 0, 15).astype(jnp.int32)
    sign = jnp.where(jnp.signbit(w), -1, 1).astype(jnp.int8)
    op = simulator.packed_operands(q, sign, scale, 0.0, 4)
    if codec != "raw":
        from repro.core import planes

        op = planes.encode_operands(op, codec)
    return op


@given(
    k8=st.integers(min_value=1, max_value=6),
    cols=st.sampled_from([4, 6, 8, 12]),
    n=st.sampled_from([2, 3, 4]),
    axis=st.sampled_from([-1, -2]),
    codec=st.sampled_from(["raw", "col_perm"]),
)
def test_shard_operands_exact(k8, cols, n, axis, codec):
    """densify(shard(op)) == shard(densify(op)) byte-for-byte, both axes."""
    size = cols if axis == -1 else k8 * 8
    if size % n or (axis == -2 and ((size // n) % 8)):
        return  # indivisible draws are plan_tp's job, not shard_operands'
    op = _rand_operands(jax.random.PRNGKey(k8 * 100 + cols), k8 * 8, cols, codec)
    dense = np.asarray(simulator.densify_operands(op))
    shards = [simulator.shard_operands(op, axis=axis, index=i, n=n) for i in range(n)]
    step = size // n
    for i, sh in enumerate(shards):
        sl = [slice(None)] * 2
        sl[axis] = slice(i * step, (i + 1) * step)
        np.testing.assert_array_equal(
            np.asarray(simulator.densify_operands(sh)), dense[tuple(sl)]
        )


def test_shard_operands_rejects_misaligned_k_slice():
    op = _rand_operands(jax.random.PRNGKey(0), 16, 4)
    with pytest.raises(ValueError, match="byte"):
        simulator.shard_operands(op, axis=-2, index=0, n=4)  # 4-row slices
    with pytest.raises(ValueError):
        simulator.shard_operands(op, axis=-1, index=2, n=2)  # index range
    with pytest.raises(ValueError):
        simulator.shard_operands(op, axis=-1, index=0, n=3)  # 4 % 3


@given(
    n=st.sampled_from([1, 2, 4]),
    heads=st.sampled_from([4, 8]),
    d_ff=st.sampled_from([32, 64]),
)
def test_shard_params_concat_roundtrip(n, heads, d_ff):
    """Per-leaf shard shapes multiply back: concatenating every shard on its
    rule axis reproduces the dense leaf; replicated leaves are shared."""
    base = get_arch("internlm2-1.8b", reduced=True)
    cfg = dataclasses.replace(
        base, n_heads=heads, n_kv_heads=heads // 2, head_dim=8, d_ff=d_ff
    )
    hd = cfg.resolved_head_dim
    key = jax.random.PRNGKey(7)
    tree = {
        "segments": {
            "0": {
                "attn": {
                    "wq": jax.random.normal(key, (2, cfg.d_model, heads * hd)),
                    "wo": jax.random.normal(key, (2, heads * hd, cfg.d_model)),
                },
                "mlp": {
                    "wi_gate": jax.random.normal(key, (2, cfg.d_model, d_ff)),
                    "wo": jax.random.normal(key, (2, d_ff, cfg.d_model)),
                },
                "norm": {"w": jax.random.normal(key, (2, cfg.d_model))},
            }
        }
    }
    plan = plan_tp(cfg, n)
    shards = [shard_params(tree, plan, i) for i in range(n)]
    flat_ref = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, ref in flat_ref:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        pieces = []
        for s in shards:
            cur = s
            for part in name.split("/"):
                cur = cur[part]
            pieces.append(np.asarray(cur))
        ax = tp._leaf_rule(name, plan)
        if ax is None or n == 1:
            for p in pieces:
                np.testing.assert_array_equal(p, np.asarray(ref))
        else:
            np.testing.assert_array_equal(
                np.concatenate(pieces, axis=ax), np.asarray(ref)
            )


# ---------------------------------------------------------------------------
# (3) serving parity: tp_generate and Engine(tp=...)
# ---------------------------------------------------------------------------

def _deployed(lm, materialize, codec):
    cfg, params = lm
    if materialize == "dense" and codec is None:
        return params
    plan = build_deployment(params, LM_SPEC, LM_CFG)
    return deploy_params(params, plan, materialize=materialize,
                         codec=codec or "raw")


@pytest.mark.parametrize(
    "materialize,codec",
    [("dense", None), ("packed", "raw"), ("packed", "col_perm")],
    ids=["dense", "packed-raw", "packed-colperm"],
)
def test_tp_generate_parity(lm, materialize, codec):
    """Token streams at shard counts {1, 2, 4} match solo serve.generate for
    every materialization; n=1 is bit-identical (psum is the identity)."""
    cfg, _ = lm
    served = _deployed(lm, materialize, codec)
    batch = {"tokens": jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (2, 7), 0, cfg.vocab_size)
    )}
    ref, _ = generate(cfg, served, batch, gen_len=6)
    ref = np.asarray(ref)
    for n in (1, 2, 4):
        toks, tps = tp_generate(cfg, served, batch, n=n, gen_len=6)
        np.testing.assert_array_equal(np.asarray(toks), ref, err_msg=f"n={n}")
        assert tps > 0


def test_tp_generate_sampled_parity(lm):
    """The sampled path shares solo's PRNG schedule shard-for-shard."""
    cfg, params = lm
    batch = {"tokens": jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(5), (1, 5), 0, cfg.vocab_size)
    )}
    ref, _ = generate(cfg, params, batch, gen_len=5, greedy=False, seed=9)
    toks, _ = tp_generate(cfg, params, batch, n=2, gen_len=5, greedy=False, seed=9)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))


@pytest.mark.parametrize("n", [2, 4])
def test_engine_tp_parity_mixed_traffic(lm, n):
    """Engine(tp=n) serves ragged greedy+sampled traffic bit-identical to the
    unsharded solo pipeline; host scheduler shapes are untouched."""
    cfg, params = lm
    eng = Engine(cfg, params, ECFG, tp=n)
    reqs = _mk_requests(cfg, [(11, 6), (5, 8), (8, 5)], greedy=True)
    reqs += _mk_requests(cfg, [(6, 6)], rid0=3, greedy=False)
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        assert res.status == "ok"
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"


def test_engine_tp_swap_preemption_parity(lm):
    """Preemption swaps per-shard paged pools (leading shard axis) out and
    back byte-identically: the -3 cell-axis indexing in paged_cache."""
    cfg, params = lm
    ecfg = dataclasses.replace(ECFG, num_blocks=7)
    eng = Engine(cfg, params, ecfg, tp=2)
    reqs = _mk_requests(cfg, [(14, 18), (13, 18)])
    results = eng.run(reqs)
    assert eng.stats["preemptions"] >= 1 and eng.stats["swap_ins"] >= 1
    for req, res in zip(reqs, results):
        assert res.status == "ok"
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"


def test_engine_tp_dispatch_from_requires_matching_plan(lm):
    cfg, params = lm
    donor = Engine(cfg, params, ECFG, tp=2)
    clone = Engine(cfg, params, ECFG, tp=2, dispatch_from=donor)
    assert clone._tp == donor._tp
    with pytest.raises(ValueError, match="dispatch_from"):
        Engine(cfg, params, ECFG, tp=4, dispatch_from=donor)


def test_fleet_sharded_replicas_parity(lm):
    """shards_per_replica plumbs through Fleet -> Replica -> Engine(tp=...);
    routing over shards-of-meshes keeps every stream solo-identical."""
    cfg, params = lm
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # single-device vmap emulation
        fleet = Fleet(
            cfg, params,
            FleetConfig(n_replicas=2, shards_per_replica=2, hedge=False), ECFG,
        )
    assert all(len(r.devices) == 2 for r in fleet.replicas)
    reqs = _mk_requests(cfg, [(5, 6), (7, 5), (6, 6), (9, 4)])
    results = fleet.run(reqs)
    for req, res in zip(reqs, results):
        assert res.status == "ok"
        assert res.tokens == _solo(cfg, params, req), f"rid {req.rid}"
    assert {r.replica for r in results} == {0, 1}


# ---------------------------------------------------------------------------
# (4) sharded pools: plan parity + wear conservation
# ---------------------------------------------------------------------------

class _PristinePool(CrossbarPool):
    """Per-tensor pristine accounting: content resets before every tensor's
    program, wear survives — the planner's parity invariant (a)."""

    def program(self, *args, **kwargs):
        self.reset()
        return super().program(*args, **kwargs)


def test_sharded_deployment_plan_matches_global(lm):
    """Round-robin tensor partitioning with the GLOBAL per-tensor PRNG
    schedule: under pristine per-tensor accounting every deployed w_hat is
    bit-identical to the unsharded (stateless) plan.  (Persistent pools
    diverge by design — each tensor reprograms over a different
    cross-tensor seam than in the unsharded stream.)"""
    cfg, params = lm
    ref = build_deployment(params, LM_SPEC, LM_CFG)
    plan, pools, owner = build_sharded_deployment(
        params, LM_SPEC, LM_CFG, 2,
        pools=[_PristinePool(LM_SPEC, LM_CFG.crossbars) for _ in range(2)],
    )
    assert set(plan.deployed) == set(ref.deployed)
    assert set(owner.values()) == {0, 1}
    for name in ref.deployed:
        np.testing.assert_array_equal(
            np.asarray(plan.deployed[name]), np.asarray(ref.deployed[name]),
            err_msg=name,
        )
    # deploy_params accepts the merged plan unchanged
    served = deploy_params(params, plan, materialize="dense")
    ref_served = deploy_params(params, ref, materialize="dense")
    for a, b in zip(jax.tree.leaves(served), jax.tree.leaves(ref_served)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n", [2, 3])
def test_sharded_pool_wear_conservation(lm, n):
    """Under per-tensor pristine accounting the shard pools' summed wear
    equals the unsharded pool's — partitioning storage loses no writes."""
    cfg, params = lm
    solo_pool = _PristinePool(LM_SPEC, LM_CFG.crossbars)
    build_deployment(params, LM_SPEC, LM_CFG, pool=solo_pool)
    shard_pools = [_PristinePool(LM_SPEC, LM_CFG.crossbars) for _ in range(n)]
    _, shard_pools, owner = build_sharded_deployment(
        params, LM_SPEC, LM_CFG, n, pools=shard_pools
    )
    total = sum(int(p.wear.sum()) for p in shard_pools)
    assert total == int(solo_pool.wear.sum())
    assert sum(p.tensors_seen for p in shard_pools) == solo_pool.tensors_seen
    assert len(owner) == solo_pool.tensors_seen


# ---------------------------------------------------------------------------
# (5) scrub under sharding
# ---------------------------------------------------------------------------

def test_sharded_scrub_storm_repairs_with_token_parity(lm):
    """A deterministic storm across per-shard pools: the round-robin budget
    lets every shard progress each round (no shard starves the others), the
    merged report sums pending across pools, and the refreshed engine serves
    bit-identical to the clean deployment."""
    cfg, params = lm
    pools = [
        CrossbarPool(LM_SPEC, LM_CFG.crossbars, leveling="lpt") for _ in range(2)
    ]
    mgrs = [
        p.enable_integrity(IntegrityConfig(spare_cols=2, scrub_tiles=1_000_000))
        for p in pools
    ]
    plan, pools, owner = build_sharded_deployment(
        params, LM_SPEC, LM_CFG, 2, pools=pools
    )
    clean = deploy_params(params, plan, materialize="dense")
    scrub = ShardedScrub(mgrs)

    eng = Engine(cfg, clean, ECFG, tp=2)
    eng.attach_scrub(
        scrub,
        refresh=lambda: deploy_params(
            params, scrub.rebuild_plan(plan), materialize="dense"
        ),
    )
    # storm BOTH pools: a mid-repair shard must not stall its peer's scan
    mgrs[0].storm(jax.random.PRNGKey(11), corrupt_rate=2e-3, stuck_rate=2e-4)
    mgrs[1].storm(jax.random.PRNGKey(12), corrupt_rate=2e-3, stuck_rate=2e-4)
    assert scrub.pending_faults() == 0  # undetected until a scrub round runs
    corrupted = deploy_params(params, scrub.rebuild_plan(plan), materialize="dense")
    assert eng.hot_swap(corrupted)
    eng.run(_mk_requests(cfg, [(11, 5), (7, 6)]))
    assert eng.stats["scrub_rounds"] > 0
    assert eng.stats["scrub_detections"] > 0
    assert eng.stats["scrub_repairs"] > 0
    assert eng.stats["scrub_refreshes"] >= 1
    assert scrub.verify_all() and scrub.pending_faults() == 0
    post = _mk_requests(cfg, [(9, 6)], rid0=10)
    res = eng.run(post)[0]
    assert res.tokens == _solo(cfg, clean, post[0])


def test_sharded_scrub_splits_round_budget():
    class _FakeMgr:
        def __init__(self):
            self.budgets = []

        def pending_faults(self):
            return 1

        def scrub_round(self, budget_tiles=None):
            self.budgets.append(budget_tiles)
            return dataclasses.make_dataclass(
                "R", ["pending"], namespace={
                    "merge": lambda self, other: None
                }
            )(pending=2)

    mgrs = [_FakeMgr() for _ in range(3)]
    scrub = ShardedScrub(mgrs)
    rep = scrub.scrub_round(budget_tiles=9)
    assert all(m.budgets == [3] for m in mgrs)  # 9 // 3 each, every shard ran
    assert rep.pending == 6  # summed across pools, not last-round-wins
    assert scrub.pending_faults() == 3
    with pytest.raises(ValueError):
        ShardedScrub([])


# ---------------------------------------------------------------------------
# native shard_map path + stable_argsort routing under an emulated mesh
# ---------------------------------------------------------------------------

_NATIVE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    assert jax.device_count() == 4

    # sws routing regression: emulated devices add execution streams, not
    # host cores — the host-callback guard must key on cores alone, and the
    # sort must stay correct either way.
    from repro.core import sws
    assert sws._use_host_sort() == (sws._usable_cores() > 1)
    keys = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    perm, inv = sws.stable_argsort(keys, with_inverse=True)
    kk = np.asarray(keys)
    np.testing.assert_array_equal(np.asarray(perm), np.argsort(kk, kind="stable"))
    np.testing.assert_array_equal(np.asarray(inv)[np.asarray(perm)], np.arange(4096))

    from repro.configs import get_arch
    from repro.launch.serve import generate
    from repro.models import api
    from repro.parallel.tp import tp_generate

    cfg = get_arch("internlm2-1.8b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, cfg.vocab_size)}
    ref, _ = generate(cfg, params, batch, gen_len=5)
    for n in (2, 4):
        toks, _ = tp_generate(cfg, params, batch, n=n, gen_len=5,
                              devices=jax.devices()[:n])
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
    print("TP_NATIVE_OK")
    """
)


@pytest.mark.slow  # fresh 4-device interpreter: jit from cold
def test_tp_native_shard_map_subprocess():
    env = dict(os.environ, PYTHONPATH="src", XLA_FLAGS="")
    out = subprocess.run(
        [sys.executable, "-c", _NATIVE_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TP_NATIVE_OK" in out.stdout


@pytest.mark.skipif(jax.device_count() < 4, reason="needs a 4-device mesh")
def test_tp_native_shard_map_parity(lm):
    """In-process native-mesh parity — runs in the multi-device CI job
    (XLA_FLAGS set before pytest), skips on the tier-1 single device."""
    cfg, params = lm
    batch = {"tokens": jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, cfg.vocab_size)
    )}
    ref, _ = generate(cfg, params, batch, gen_len=5)
    for n in (2, 4):
        toks, _ = tp_generate(
            cfg, params, batch, n=n, gen_len=5, devices=jax.devices()[:n]
        )
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
