"""Three-way parity: Pallas hamming kernel vs packed popcount vs bool planes.

The packed-plane invariant (see ``core.cost``) promises that every pricing
route — the Pallas ``hamming_pairs_kernel`` (interpret mode off-TPU), the
portable ``pair_transitions_packed`` popcount, and the readable bool
``pair_transitions`` oracle — returns identical counts, including on ragged
pair counts that force kernel-side padding and on all-zero pristine-state
pairs (the synthetic ``prev = -1`` state of ``schedule.chain_pairs``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitslice, cost, schedule, stucking
from repro.kernels.hamming import ops as hm_ops
from repro.kernels.hamming import ref as hm_ref


def _random_sections(seed: int, t: int, rows: int, cols: int) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2, (t, rows, cols)), jnp.bool_)


# T values chosen to exercise kernel padding: 1 and 7 pad up to the block
# multiple, 300 is ragged over the default bt, 256 is exact.
@pytest.mark.parametrize("t", [1, 7, 256, 300])
@pytest.mark.parametrize("rows,cols", [(24, 6), (128, 10)])
def test_three_way_pair_parity(t, rows, cols):
    a = _random_sections(t, t, rows, cols)
    b = _random_sections(t + 1, t, rows, cols)
    pa, pb = bitslice.pack_rows(a), bitslice.pack_rows(b)

    want = cost.pair_transitions(a, b)  # bool oracle
    np.testing.assert_array_equal(cost.pair_transitions_packed(pa, pb), want)
    np.testing.assert_array_equal(hm_ref.hamming_pairs(pa, pb), want)
    # ops wrapper pads T and runs the Pallas kernel (interpret mode on CPU)
    np.testing.assert_array_equal(hm_ops.hamming_pairs(pa, pb, interpret=True), want)
    # the planner's dispatcher (popcount fallback on CPU, kernel on TPU)
    np.testing.assert_array_equal(hm_ops.price_pairs(pa, pb), want)


def test_pristine_state_pairs():
    """All-zero 'prev' operands (first program of every chain) price to the
    popcount of the target alone, on every route."""
    b = _random_sections(3, 9, 40, 8)
    pb = bitslice.pack_rows(b)
    zeros_b = jnp.zeros_like(b)
    zeros_p = jnp.zeros_like(pb)

    want = jnp.sum(b, axis=(1, 2), dtype=jnp.int32)
    np.testing.assert_array_equal(cost.pair_transitions(zeros_b, b), want)
    np.testing.assert_array_equal(cost.pair_transitions_packed(zeros_p, pb), want)
    np.testing.assert_array_equal(hm_ops.hamming_pairs(zeros_p, pb, interpret=True), want)
    # and zero-vs-zero is free
    assert int(jnp.sum(hm_ops.price_pairs(zeros_p, zeros_p))) == 0


@pytest.mark.parametrize("include_initial", [True, False])
@pytest.mark.parametrize("kind", ["stride1", "strideL"])
def test_batched_schedule_pricing_matches_looped_reference(kind, include_initial):
    """One batched price_pairs call == the seed per-chain Python loop,
    job-for-job, for bool and packed inputs alike."""
    planes = _random_sections(11, 60, 32, 8)
    chains = schedule.make_chains(60, 7, kind)
    want = schedule.schedule_job_costs_looped(
        planes, chains, include_initial=include_initial
    )
    got_bool = schedule.schedule_job_costs(planes, chains, include_initial=include_initial)
    got_packed = schedule.schedule_job_costs(
        bitslice.pack_rows(planes), chains, include_initial=include_initial
    )
    np.testing.assert_array_equal(got_bool, want)
    np.testing.assert_array_equal(got_packed, want)


def test_chain_cost_packed_matches_bool(key):
    planes = jax.random.bernoulli(key, 0.5, (20, 48, 10))
    packed = bitslice.pack_rows(planes)
    order = jnp.asarray(np.random.default_rng(0).permutation(20), jnp.int32)
    for include_initial in (True, False):
        assert int(cost.chain_transitions_packed(packed, order, include_initial=include_initial)) == int(
            cost.chain_transitions(planes, order, include_initial=include_initial)
        )
        np.testing.assert_array_equal(
            cost.consecutive_costs_packed(packed, order, include_initial=include_initial),
            cost.consecutive_costs(planes, order, include_initial=include_initial),
        )
    np.testing.assert_array_equal(
        cost.chain_transitions_packed(packed, per_column=True),
        cost.chain_transitions(planes, per_column=True),
    )


@pytest.mark.parametrize("p", [0.0, 0.5, 1.0])
def test_stuck_schedule_packed_bit_exact_with_bool(key, p):
    """Same key schedule + same Bernoulli mask shape -> identical achieved
    planes and identical programmed-transition totals."""
    rows, cols, s = 40, 8, 30  # rows deliberately not a multiple of 8
    planes = jax.random.bernoulli(key, 0.4, (s, rows, cols))
    packed = bitslice.pack_rows(planes)
    chains = schedule.stride_1_chains(s, 4)

    total_b, achieved_b = stucking.stuck_schedule(planes, chains, p, key, stuck_cols=2)
    chain_totals_p, achieved_p = stucking.stuck_schedule_packed(
        packed, chains, p, key, rows=rows, stuck_cols=2
    )
    assert int(total_b) == int(np.sum(np.asarray(chain_totals_p), dtype=np.int64))
    np.testing.assert_array_equal(bitslice.unpack_rows(achieved_p, rows), achieved_b)
