"""Trip-count-aware HLO cost analyzer vs analytic ground truth."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _scan_model(layers: int, d: int):
    def fwd(ws, x):
        def body(xc, w):
            return jnp.tanh(xc @ w), None

        xc, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(xc)

    return fwd


def test_scan_flops_scaled_by_trip_count(key):
    layers, d, n = 8, 64, 32
    ws = jax.random.normal(key, (layers, d, d))
    x = jax.random.normal(key, (n, d))
    compiled = jax.jit(_scan_model(layers, d)).lower(ws, x).compile()
    c = hlo_cost.analyze(compiled.as_text())
    analytic = 2 * n * d * d * layers
    assert c.n_while == 1 and c.max_trip == layers
    assert abs(c.flops - analytic) / analytic < 0.05
    # raw HloCostAnalysis counts the body once -> ~layers-fold undercount
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib returns [dict] per module
        ca = ca[0]
    raw = ca["flops"]
    assert raw < analytic / (layers / 2)


def test_unrolled_matches_scan_totals(key):
    layers, d, n = 4, 32, 16
    ws = jax.random.normal(key, (layers, d, d))
    x = jax.random.normal(key, (n, d))

    def unrolled(ws, x):
        for i in range(layers):
            x = jnp.tanh(x @ ws[i])
        return jnp.sum(x)

    c_scan = hlo_cost.analyze(
        jax.jit(_scan_model(layers, d)).lower(ws, x).compile().as_text()
    )
    c_unroll = hlo_cost.analyze(jax.jit(unrolled).lower(ws, x).compile().as_text())
    assert abs(c_scan.flops - c_unroll.flops) / c_unroll.flops < 0.05


def test_grad_flops_ratio(key):
    """d(loss)/d(ws) + d(loss)/d(x) costs ~3x the forward matmul FLOPs."""
    layers, d, n = 4, 64, 32
    ws = jax.random.normal(key, (layers, d, d))
    x = jax.random.normal(key, (n, d))
    f = _scan_model(layers, d)
    fwd = hlo_cost.analyze(jax.jit(f).lower(ws, x).compile().as_text()).flops
    g = jax.jit(jax.grad(f, argnums=(0, 1)))
    bwd = hlo_cost.analyze(g.lower(ws, x).compile().as_text()).flops
    assert 2.2 <= bwd / fwd <= 3.8


def test_bytes_positive_and_flops_zero_for_elementwise(key):
    x = jax.random.normal(key, (128, 128))
    compiled = jax.jit(lambda a: jnp.tanh(a) + 1.0).lower(x).compile()
    c = hlo_cost.analyze(compiled.as_text())
    assert c.flops == 0.0
    assert c.bytes_accessed >= 2 * x.size * 4  # read + write at least once
