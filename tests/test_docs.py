"""The docs reference checker runs green: every internal link, anchor,
repo path, and `repro.*` module reference in README.md + docs/ resolves
against the working tree.  CI runs the same script as a standalone job;
having it in tier-1 means a rename that orphans the paper→code map fails
the local suite too, not just CI.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_references_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True, cwd=REPO,
    )
    assert proc.returncode == 0, f"dead doc references:\n{proc.stderr}"


def test_docs_tree_exists():
    for page in ("architecture.md", "paper_map.md", "benchmarks.md"):
        assert (REPO / "docs" / page).is_file(), f"docs/{page} missing"
