"""Tests for bit-stucking-based reprogramming (§IV)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitslice, cost, schedule, stucking, sws


def _sorted_planes(key, s=64, rows=64, cols=8):
    w = jax.random.normal(key, (rows * s,)) * 0.02
    qt = bitslice.quantize(w, cols)
    perm = sws.sws_permutation(w)
    return bitslice.bitplanes(qt.q[perm].reshape(s, rows), cols)


def test_p1_matches_full_reprogramming(key):
    planes = _sorted_planes(key)
    order = jnp.arange(planes.shape[0], dtype=jnp.int32)
    total, achieved = stucking.stuck_chain(planes, order, 1.0, key)
    assert int(total) == int(cost.chain_transitions(planes, order))
    np.testing.assert_array_equal(achieved, planes)


def test_p0_sticks_lsb_forever(key):
    planes = _sorted_planes(key)
    order = jnp.arange(planes.shape[0], dtype=jnp.int32)
    total, achieved = stucking.stuck_chain(planes, order, 0.0, key)
    # LSB column never changes after the first program: every section's
    # achieved LSB equals the first section's ideal LSB... except the first
    # program itself is also subject to stucking from the pristine (all-zero)
    # state, so the stuck LSB is all-zero.
    lsb = achieved[..., 0]
    assert int(jnp.sum(lsb)) == 0
    # high-order columns are fully programmed
    np.testing.assert_array_equal(achieved[..., 1:], planes[..., 1:])
    # cost = full cost minus all LSB transitions
    per_col = cost.chain_transitions(planes, order, per_column=True)
    assert int(total) == int(jnp.sum(per_col[1:]))


def test_cost_monotone_in_p(key):
    planes = _sorted_planes(key)
    order = jnp.arange(planes.shape[0], dtype=jnp.int32)
    totals = [
        int(stucking.stuck_chain(planes, order, p, jax.random.PRNGKey(7))[0])
        for p in (0.0, 0.25, 0.5, 0.75, 1.0)
    ]
    assert all(a <= b for a, b in zip(totals, totals[1:]))


def test_measured_saving_matches_analytic(key):
    planes = _sorted_planes(key, s=128)
    order = jnp.arange(planes.shape[0], dtype=jnp.int32)
    p = 0.5
    full = int(cost.chain_transitions(planes, order))
    got = int(stucking.stuck_chain(planes, order, p, key)[0])
    predicted = float(stucking.expected_saving_fraction(planes, order, p))
    measured = (full - got) / full
    # Bernoulli(p) across thousands of memristors: within a few percent.
    # NOTE the analytic formula ignores second-order re-transition effects
    # (a skipped flip can cancel a later flip), so the tolerance is loose.
    assert abs(measured - predicted) < 0.1


def test_stuck_cols_2_saves_more_than_1(key):
    planes = _sorted_planes(key)
    order = jnp.arange(planes.shape[0], dtype=jnp.int32)
    t1 = int(stucking.stuck_chain(planes, order, 0.3, key, stuck_cols=1)[0])
    t2 = int(stucking.stuck_chain(planes, order, 0.3, key, stuck_cols=2)[0])
    assert t2 < t1


def test_stuck_schedule_combines_chains(key):
    planes = _sorted_planes(key, s=60)
    chains = schedule.stride_1_chains(60, 8)
    total, achieved = stucking.stuck_schedule(planes, chains, 1.0, key)
    assert int(total) == int(schedule.schedule_transitions(planes, chains))
    np.testing.assert_array_equal(achieved, planes)

    total_h, achieved_h = stucking.stuck_schedule(planes, chains, 0.5, key)
    assert int(total_h) <= int(total)
    # only the LSB column may deviate from ideal
    np.testing.assert_array_equal(achieved_h[..., 1:], planes[..., 1:])


def test_schedule_padding_steps_are_free(key):
    """Regression: schedule-padding steps (repeating a chain's last section)
    must be complete no-ops under p < 1 — previously each padded step redrew
    a Bernoulli mask and kept reprogramming residual stuck bits, so a
    section's achieved state depended on how much padding its chain got (and
    the combining scatter saw duplicate indices with differing values)."""
    planes = _sorted_planes(key, s=8, rows=32, cols=8)
    packed = bitslice.pack_rows(planes)
    order = jnp.array([3, 5, 5, 5], jnp.int32)  # last section 'padded' twice
    valid = jnp.array([True, True, False, False])

    _, states = stucking._walk_packed(
        packed, order, 0.5, key, rows=32, stuck_cols=2, include_initial=True, valid=valid
    )
    # state frozen across the masked steps (p=0.5 leaves residual stuck-bit
    # transitions that an unmasked retry would program)
    np.testing.assert_array_equal(states[1], states[2])
    np.testing.assert_array_equal(states[1], states[3])

    t_b, ach_b = stucking.stuck_chain(planes, order, 0.5, key, stuck_cols=2, valid=valid)
    t_p, _ = stucking.stuck_chain_packed(
        packed, order, 0.5, key, rows=32, stuck_cols=2, valid=valid
    )
    assert int(t_b) == int(t_p)


def test_achieved_error_is_lsb_bounded(key):
    """Deployed weights deviate from ideal by at most the LSB multiplier."""
    rows, cols, s = 32, 8, 40
    w = jax.random.normal(key, (rows * s,)) * 0.02
    qt = bitslice.quantize(w, cols)
    perm = sws.sws_permutation(w)
    planes = bitslice.bitplanes(qt.q[perm].reshape(s, rows), cols)
    order = jnp.arange(s, dtype=jnp.int32)
    _, achieved = stucking.stuck_chain(planes, order, 0.0, key)
    sign = jnp.sign(w)[perm].reshape(s, rows).astype(jnp.int8)
    sign = jnp.where(sign == 0, 1, sign)
    w_hat = bitslice.dequantize_from_planes(achieved, sign, qt.scale, qt.offset)
    w_ideal = bitslice.dequantize_from_planes(planes, sign, qt.scale, qt.offset)
    assert float(jnp.max(jnp.abs(w_hat - w_ideal))) <= float(qt.scale) + 1e-7
