"""Runtime: train loop learns, survives crashes, detects stragglers; data
pipeline is deterministic and shardable."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import DataConfig, make_dataset
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultPolicy, StragglerPolicy, TrainLoop, TrainLoopConfig
from repro.runtime.fault import run_with_retries


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step_host():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8)
    ds = make_dataset(cfg)
    a = ds.batch_at(3, host=1, n_hosts=2)["tokens"]
    b = ds.batch_at(3, host=1, n_hosts=2)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = ds.batch_at(4, host=1, n_hosts=2)["tokens"]
    assert not np.array_equal(a, c)
    d = ds.batch_at(3, host=0, n_hosts=2)["tokens"]
    assert not np.array_equal(a, d)


def test_data_host_sharding_sizes():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=8)
    ds = make_dataset(cfg)
    assert ds.host_batch(4) == 2
    with pytest.raises(ValueError):
        ds.host_batch(3)
    tok = ds.batch_at(0, 0, 4)["tokens"]
    assert tok.shape == (2, 16)
    assert int(tok.min()) >= 0 and int(tok.max()) < 512


def test_copy_task_structure():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2, task="copy")
    tok = np.asarray(make_dataset(cfg).batch_at(0)["tokens"])
    np.testing.assert_array_equal(tok[:, 1:], (5 * tok[:, :-1] + 7) % 64)


# ---------------------------------------------------------------------------
# train loop
# ---------------------------------------------------------------------------

def _loop(tmp_path, steps=24, arch="internlm2-1.8b", fault=None, task="copy"):
    cfg = get_arch(arch, reduced=True)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
    ds = make_dataset(DataConfig(cfg.vocab_size, 32, 4, task=task))

    def init_state():
        params = api.init(jax.random.PRNGKey(0), cfg)
        return params, adamw_init(params)

    return TrainLoop(
        cfg,
        TrainLoopConfig(
            total_steps=steps, checkpoint_every=8, checkpoint_dir=str(tmp_path),
            log_every=4, redeploy_every=0,
        ),
        train_step=step_fn,
        init_state=init_state,
        dataset=ds,
        fault=fault or FaultPolicy(max_retries=1),
    )


def test_loop_learns_copy_task(tmp_path):
    loop = _loop(tmp_path)
    result = loop.run()
    log = result["metrics_log"]
    assert log[-1]["loss"] < log[0]["loss"]  # loss went down
    assert log[-1]["step"] == 24


def test_loop_resumes_from_checkpoint(tmp_path):
    loop1 = _loop(tmp_path, steps=8)
    loop1.run()
    loop2 = _loop(tmp_path, steps=16)
    assert loop2.start_step == 8  # picked up the step-8 checkpoint
    result = loop2.run()
    assert result["metrics_log"][-1]["step"] == 16


def test_step_retry_on_transient_failure(tmp_path):
    loop = _loop(tmp_path, steps=6, fault=FaultPolicy(max_retries=2))
    orig = loop.train_step
    fails = {"n": 0}

    def flaky(params, opt_state, batch):
        if fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected node failure")
        return orig(params, opt_state, batch)

    loop.train_step = flaky
    result = loop.run()
    assert fails["n"] == 2  # failed twice, then recovered
    assert result["metrics_log"][-1]["step"] == 6


def test_retries_exhausted_raises(tmp_path):
    loop = _loop(tmp_path, steps=4, fault=FaultPolicy(max_retries=1))

    def always_fail(params, opt_state, batch):
        raise RuntimeError("dead node")

    loop.train_step = always_fail
    with pytest.raises(RuntimeError, match="failed after 2 attempts"):
        loop.run()


def test_straggler_policy_marks_and_swaps():
    pol = StragglerPolicy(tolerance=2.0, demote_after=2, warmup_steps=0)
    swaps = []
    for step in range(10):
        pol.observe(step, 1.0)
    assert not pol.events
    # two consecutive 5x-slow steps -> mark, mark, swap request
    pol.observe(10, 5.0, swap_fn=lambda: swaps.append(10))
    pol.observe(11, 5.0, swap_fn=lambda: swaps.append(11))
    assert swaps == [11]
    assert any(e.get("action") == "request_spare_swap" for e in pol.events)


def test_retry_on_filter_passes_other_exceptions_through():
    """Exceptions outside ``retry_on`` re-raise unchanged on first occurrence
    — no retries burned, no RuntimeError wrapper."""
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        raise KeyError("not a transient fault")

    with pytest.raises(KeyError):
        run_with_retries(fn, FaultPolicy(max_retries=3), retry_on=(ValueError,))
    assert calls["n"] == 1


def test_keyboard_interrupt_never_retried():
    """A shutdown request must cross the retry boundary untouched, even when
    ``retry_on`` is (deliberately or accidentally) maximally broad."""
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        run_with_retries(fn, FaultPolicy(max_retries=3), retry_on=(BaseException,))
    assert calls["n"] == 1


def test_no_backoff_sleep_after_final_attempt(monkeypatch):
    """Backoff only runs when another attempt follows: max_retries=2 means
    3 attempts but only 2 sleeps."""
    import repro.runtime.fault as fault_mod

    sleeps: list[float] = []
    monkeypatch.setattr(fault_mod.time, "sleep", sleeps.append)

    def fn():
        raise RuntimeError("down")

    with pytest.raises(RuntimeError, match="failed after 3 attempts"):
        run_with_retries(fn, FaultPolicy(max_retries=2, backoff_s=0.01))
    assert sleeps == [0.01, 0.02]  # exponential, and none after the last try


def test_straggler_marks_reset_on_fast_step():
    """Marks must be *consecutive*: a fast step between two slow ones
    prevents demotion."""
    pol = StragglerPolicy(tolerance=2.0, demote_after=2, warmup_steps=0)
    for step in range(5):
        pol.observe(step, 1.0)
    swaps = []
    pol.observe(5, 5.0, swap_fn=lambda: swaps.append(5))
    pol.observe(6, 1.0)  # recovers: resets the consecutive-mark counter
    pol.observe(7, 5.0, swap_fn=lambda: swaps.append(7))
    assert swaps == []
    assert not any(e.get("action") == "request_spare_swap" for e in pol.events)


def test_straggler_ewma_resets_after_swap():
    """After a spare swap the EWMA is forgotten: the replacement host's
    first step re-seeds the baseline instead of being judged against the
    dead host's history (a fast replacement must not look 'normal-fast'
    and a 3x-slower-than-dead-host replacement must not be demoted)."""
    pol = StragglerPolicy(tolerance=2.0, demote_after=1, warmup_steps=0)
    for step in range(5):
        pol.observe(step, 1.0)
    assert pol.observe(5, 10.0, swap_fn=lambda: None)  # demoted immediately
    assert pol._ewma is None and pol._marks == 0
    # replacement host is 4x slower than the old baseline: first observation
    # re-seeds, second (same speed) is NOT straggling
    assert not pol.observe(6, 4.0)
    assert not pol.observe(7, 4.0)
    assert pol._ewma == pytest.approx(4.0, rel=0.2)


def test_redeploy_pricing_in_loop(tmp_path):
    loop = _loop(tmp_path, steps=8)
    loop.loop_cfg = TrainLoopConfig(
        total_steps=8, checkpoint_every=8, checkpoint_dir=str(tmp_path),
        log_every=4, redeploy_every=4,
    )
    result = loop.run()
    # first pricing at step 4 only snapshots; step 8 prices the delta
    assert len(result["redeploy_log"]) >= 1
    rec = result["redeploy_log"][0]
    assert rec["transitions_sws"] <= rec["n_bits"]


def test_backoff_delay_jittered_bounded_and_seed_deterministic():
    """The jittered delay stays within [base, base*(1+jitter)] per attempt
    and replays identically for a fixed seed — N replicas spread out, one
    trace reproduces."""
    import random

    from repro.runtime.fault import backoff_delay

    pol = FaultPolicy(max_retries=5, backoff_s=0.1, jitter=0.5, seed=42)
    rng1, rng2 = random.Random(42), random.Random(42)
    d1 = [backoff_delay(pol, a, rng1) for a in range(4)]
    d2 = [backoff_delay(pol, a, rng2) for a in range(4)]
    assert d1 == d2
    for a, d in enumerate(d1):
        base = 0.1 * 2**a
        assert base <= d <= base * 1.5
    assert len({d / 0.1 / 2**a for a, d in enumerate(d1)}) > 1  # actually jittered
    # zero base short-circuits (no RNG draw), jitter-off is exact exponential
    assert backoff_delay(FaultPolicy(backoff_s=0.0, jitter=0.5), 3) == 0.0
    assert backoff_delay(FaultPolicy(backoff_s=0.2), 3) == pytest.approx(1.6)
    with pytest.raises(ValueError, match="jitter"):
        FaultPolicy(jitter=-0.1)


def test_run_with_retries_jittered_sleeps_deterministic(monkeypatch):
    """Jittered backoff keeps both PR-6 invariants: sleeps only between
    attempts (never after the final one), and a fixed policy seed replays
    the identical sleep trace."""
    import repro.runtime.fault as fault_mod

    sleeps: list[float] = []
    monkeypatch.setattr(fault_mod.time, "sleep", sleeps.append)

    def fn():
        raise RuntimeError("down")

    pol = FaultPolicy(max_retries=2, backoff_s=0.01, jitter=1.0, seed=7)
    with pytest.raises(RuntimeError, match="failed after 3 attempts"):
        run_with_retries(fn, pol)
    assert len(sleeps) == 2  # 3 attempts, no sleep after the last
    first = list(sleeps)
    sleeps.clear()
    with pytest.raises(RuntimeError):
        run_with_retries(fn, pol)
    assert sleeps == first  # seeded jitter: bit-identical trace
    for a, s in enumerate(first):
        base = 0.01 * 2**a
        assert base <= s <= base * 2.0
