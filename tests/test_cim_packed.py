"""Bit-plane-native serving: packed kernel parity + operand export + serve.

Pins the packed serving contract end to end:
  * the packed Pallas kernel (interpret mode) against the packed reference,
    the int8-plane kernel modes, and the dense quantized matmul — across both
    encodings, odd K not divisible by 8, and degenerate decode shapes;
  * operand export: ``deploy_params(materialize=...)`` re-encodings are exact
    (same achieved weights as the dense materialization, stucking included);
  * serving: packed/int8 deployments generate bit-identical tokens to the
    dense deployment, and the scan decode loop matches the python loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import bitslice, simulator
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.kernels.cim_matmul import ops as cm_ops, ref as cm_ref
from repro.launch.serve import generate
from repro.models import api, layers


def _packed_operands(w, cols, encoding="sign_magnitude"):
    qt = bitslice.quantize(w, cols, encoding)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    return (
        bitslice.pack_linear_planes(q, cols),
        bitslice.pack_linear_sign(sign),
        qt,
    )


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n,cols",
    [
        (4, 32, 16, 4),
        (17, 100, 60, 8),   # K not divisible by 8
        (128, 128, 128, 10),
        (1, 7, 3, 10),      # degenerate decode shapes
        (3, 9, 130, 6),
        (8, 1, 1, 2),
        (300, 40, 5, 10),   # M larger than one chunk-of-8
    ],
)
def test_packed_kernel_vs_ref(m, k, n, cols):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.1
    pp, sp, qt = _packed_operands(w, cols)
    got = cm_ops.cim_matmul_packed(x, pp, sp, qt.scale, interpret=True)
    want = cm_ref.cim_matmul_packed(x, pp, sp, qt.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # and against the dense quantized matmul (the end-to-end contract)
    w_hat = bitslice.dequantize(qt).reshape(w.shape)
    np.testing.assert_allclose(got, x @ w_hat, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["fused_dequant", "planes"])
def test_packed_kernel_vs_int8_modes(mode, key):
    kx, kw = jax.random.split(key)
    m, k, n, cols = 8, 96, 48, 10
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.1
    pp, sp, qt = _packed_operands(w, cols)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    splanes = jnp.moveaxis(bitslice.bitplanes(q, cols).astype(jnp.int8) * sign[..., None], -1, 0)
    got = cm_ops.cim_matmul_packed(x, pp, sp, qt.scale, interpret=True)
    want = cm_ops.cim_matmul(x, splanes, qt.scale, mode=mode, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_packed_kernel_m_chunking(key):
    """M chunking concatenates cleanly (chunk boundary not an M multiple)."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (67, 40))
    w = jax.random.normal(kw, (40, 24)) * 0.1
    pp, sp, qt = _packed_operands(w, 6)
    got = cm_ops.cim_matmul_packed(x, pp, sp, qt.scale, m_chunk=16, interpret=True)
    want = cm_ref.cim_matmul_packed(x, pp, sp, qt.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (2, 3, 5), (5, 8, 8), (8, 130, 7)])
def test_int8_kernel_degenerate_shapes(m, k, n):
    """Tiny decode shapes through the int8 kernel path (block clamp fix)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m + 10 * k + 100 * n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.1
    qt = bitslice.quantize(w, 6)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    sp8 = jnp.moveaxis(bitslice.bitplanes(q, 6).astype(jnp.int8) * sign[..., None], -1, 0)
    got = cm_ops.cim_matmul(x, sp8, qt.scale)
    want = cm_ref.cim_matmul(x, sp8, qt.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_block_clamp_non_hardware_block_sizes(key):
    """Caller-supplied block sizes that aren't tile multiples are normalized
    (the seed clamp could emit a bm not divisible by 8)."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (20, 70))
    w = jax.random.normal(kw, (70, 33)) * 0.1
    qt = bitslice.quantize(w, 4)
    q = qt.q.reshape(w.shape)
    sign = qt.sign.reshape(w.shape)
    sp8 = jnp.moveaxis(bitslice.bitplanes(q, 4).astype(jnp.int8) * sign[..., None], -1, 0)
    got = cm_ops.cim_matmul(x, sp8, qt.scale, bm=20, bn=100, bk=100)
    want = cm_ref.cim_matmul(x, sp8, qt.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    pp, sp = bitslice.pack_linear_planes(q, 4), bitslice.pack_linear_sign(sign)
    got_p = cm_ops.cim_matmul_packed(x, pp, sp, qt.scale, bn=100, bk=100, interpret=True)
    np.testing.assert_allclose(got_p, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("encoding", ["sign_magnitude", "offset_binary"])
def test_cim_linear_packed_both_encodings(encoding, key):
    """Packed operands through cim_linear (rank-1 offset correction included)."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (4, 64))
    w = jax.random.normal(kw, (64, 32)) * 0.1 + 0.05
    spec = CrossbarSpec(rows=128, cols=10, encoding=encoding)
    ops_p = simulator.prepare_linear(w, spec, materialize="packed")
    y = simulator.cim_linear(x, ops_p)
    w_hat = bitslice.dequantize(bitslice.quantize(w, 10, encoding)).reshape(w.shape)
    np.testing.assert_allclose(y, x @ w_hat, rtol=1e-4, atol=1e-4)
    # int8 materialization of the same weight agrees
    y8 = simulator.cim_linear(x, simulator.prepare_linear(w, spec))
    np.testing.assert_allclose(y, y8, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Operand export (deploy_params materializations)
# ---------------------------------------------------------------------------

def test_operands_from_dense_bit_exact_planes(key):
    """Packed operands recovered from dense w_hat equal the ones built from
    the quantizer's own q — bit for bit, both encodings, stucking included."""
    w = jax.random.normal(key, (96, 40)) * 0.1
    for encoding in ("sign_magnitude", "offset_binary"):
        qt = bitslice.quantize(w, 10, encoding)
        w_hat = bitslice.dequantize(qt).reshape(w.shape)
        got = simulator.operands_from_dense(w_hat, qt.scale, qt.offset, encoding, 10)
        q = qt.q.reshape(w.shape)
        sign = qt.sign.reshape(w.shape)
        np.testing.assert_array_equal(got["planes_packed"], bitslice.pack_linear_planes(q, 10))
        np.testing.assert_array_equal(got["sign_packed"], bitslice.pack_linear_sign(sign))


def test_densify_packed_roundtrip(key):
    w = jax.random.normal(key, (40, 24)) * 0.1
    qt = bitslice.quantize(w, 10)
    w_hat = bitslice.dequantize(qt).reshape(w.shape)
    op = simulator.operands_from_dense(w_hat, qt.scale, qt.offset, "sign_magnitude", 10)
    np.testing.assert_allclose(simulator.densify_operands(op), w_hat, rtol=1e-6, atol=1e-7)
    # pytree walk: nested params with dense leaves left alone
    tree = {"a": {"w": op}, "b": w}
    out = simulator.densify_packed(tree)
    assert out["b"] is w and not simulator.is_cim_operands(out["a"]["w"])


def test_layers_linear_batched_operands(key):
    """Stacked (expert/layer) operand dicts vmap against stacked activations."""
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (3, 32, 16)) * 0.1  # [E, K, N]
    x = jax.random.normal(kx, (3, 5, 32))  # [E, cap, K]
    qt = bitslice.quantize(w, 10)
    w_hat = bitslice.dequantize(qt).reshape(w.shape)
    op = simulator.operands_from_dense(w_hat, qt.scale, qt.offset, "sign_magnitude", 10)
    y = layers.linear(op, x, jnp.float32)
    np.testing.assert_allclose(y, x @ w_hat, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Serving end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def deployed_gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 12)
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10),
        PlannerConfig(p_stuck=0.5, min_size=1024),
    )
    return cfg, params, batch, plan


def test_serve_packed_tokens_match_dense(deployed_gemma):
    """The acceptance contract: packed / int8 materializations generate
    bit-identical tokens to the dense-materialized deployment."""
    cfg, params, batch, plan = deployed_gemma
    toks = {}
    for mat in ("dense", "packed", "planes_int8"):
        p = deploy_params(params, plan, materialize=mat)
        toks[mat], _ = generate(cfg, p, batch, gen_len=6)
    np.testing.assert_array_equal(toks["dense"], toks["packed"])
    np.testing.assert_array_equal(toks["dense"], toks["planes_int8"])


@pytest.mark.parametrize("codec", ["const_rle", "col_perm", "col_perm_rle"])
def test_serve_codec_tokens_match_dense(deployed_gemma, codec):
    """ISSUE acceptance: the serve token stream is bit-identical to dense
    for every plane codec (codec-encoded operand dicts decode exactly)."""
    cfg, params, batch, plan = deployed_gemma
    toks_dense, _ = generate(cfg, deploy_params(params, plan), batch, gen_len=6)
    p = deploy_params(params, plan, materialize="packed", codec=codec)
    toks, _ = generate(cfg, p, batch, gen_len=6)
    np.testing.assert_array_equal(toks_dense, toks)


def test_serve_planner_codec_end_to_end(key):
    """Full pipeline with the codec in the *planner* (col_perm_rle physical
    storage) — deployed weights and forward logits match the raw-codec plan."""
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 8)
    spec = CrossbarSpec(rows=128, cols=10)
    plan_raw = build_deployment(params, spec, PlannerConfig(p_stuck=1.0, min_size=1024))
    plan_enc = build_deployment(
        params, spec, PlannerConfig(p_stuck=1.0, min_size=1024, codec="col_perm_rle")
    )
    la, _ = api.forward(deploy_params(params, plan_raw), cfg, batch)
    lb, _ = api.forward(deploy_params(params, plan_enc), cfg, batch)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    t_raw = sum(r.transitions_sws for r in plan_raw.reports.values())
    t_enc = sum(r.transitions_sws for r in plan_enc.reports.values())
    assert t_enc <= t_raw


def test_serve_scan_matches_python_loop(deployed_gemma):
    cfg, params, batch, plan = deployed_gemma
    p = deploy_params(params, plan, materialize="packed")
    for greedy in (True, False):
        a, _ = generate(cfg, p, batch, gen_len=6, greedy=greedy, seed=7, loop="scan")
        b, _ = generate(cfg, p, batch, gen_len=6, greedy=greedy, seed=7, loop="python")
        np.testing.assert_array_equal(a, b)


def test_forward_packed_logits_close(deployed_gemma):
    cfg, params, batch, plan = deployed_gemma
    la, _ = api.forward(deploy_params(params, plan), cfg, batch)
    lb, _ = api.forward(deploy_params(params, plan, materialize="packed"), cfg, batch)
    np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-4)


def test_moe_forward_packed_matches_dense(key):
    """Expert-stacked weights route through the vmapped operand path."""
    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 8)
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10), PlannerConfig(p_stuck=1.0, min_size=512)
    )
    la, _ = api.forward(deploy_params(params, plan), cfg, batch)
    lb, _ = api.forward(deploy_params(params, plan, materialize="packed"), cfg, batch)
    np.testing.assert_allclose(la, lb, rtol=2e-4, atol=2e-4)


def test_decode_step_consumes_packed_operands(deployed_gemma):
    """Per-step decode computes directly on operand dicts (the TPU serving
    dataflow — no densify hop in between)."""
    cfg, params, batch, plan = deployed_gemma
    b = batch["tokens"].shape[0]
    cache_d = api.init_cache(cfg, b, 4)
    cache_p = api.init_cache(cfg, b, 4)
    tok = batch["tokens"][:, :1]
    la, _ = api.decode_step(deploy_params(params, plan), cfg, cache_d, tok, jnp.int32(0))
    lb, _ = api.decode_step(
        deploy_params(params, plan, materialize="packed"), cfg, cache_p, tok, jnp.int32(0)
    )
    np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-4)


@pytest.mark.slow  # full reduced-model deploy + two forwards per family
@pytest.mark.parametrize(
    "arch", ["deepseek-v2-236b", "xlstm-350m", "hymba-1.5b", "seamless-m4t-medium"]
)
def test_families_forward_packed_matches_dense(arch, key):
    """Every model family's routed matmul sites accept packed operands
    (MATERIALIZE_DENSE_ONLY covers the non-matmul consumers)."""
    cfg = get_arch(arch, reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 8)
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10), PlannerConfig(p_stuck=1.0, min_size=512)
    )
    la, _ = api.forward(deploy_params(params, plan), cfg, batch)
    lb, _ = api.forward(deploy_params(params, plan, materialize="packed"), cfg, batch)
    np.testing.assert_allclose(la, lb, rtol=2e-4, atol=2e-4)
