"""Device-realistic fault layer (core/nonideal.py): deterministic injection,
zero-fault parity pins, fault-aware remapping, and serving-side perturbation.

The two contracts everything else leans on:

(1) zero-fault parity — a ``FaultModel()`` with every rate at 0.0 yields
    all-zero masks, so the non-ideal read is the bitwise identity: pool
    ``achieved_read`` planes byte-identical to ``achieved``, deployed
    params byte-identical to a fault-free deployment across all
    materializations, and engine token streams bit-identical to the clean
    path;
(2) the serving perturbation (``perturb_operands``) and the dense fold
    (``densify_operands``) describe the same faulty device: ``cim_linear``
    on perturbed operands equals ``x @ densify(perturbed)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, strategies as st

from repro.configs import get_arch
from repro.core import bitslice, nonideal, schedule, simulator
from repro.core.planner import (
    MATERIALIZATIONS,
    CrossbarSpec,
    PlannerConfig,
    build_deployment,
    deploy_params,
)
from repro.core.pool import CrossbarPool
from repro.launch.engine import Engine, EngineConfig, Request
from repro.launch.serve import generate
from repro.models import api

SPEC = CrossbarSpec(rows=64, cols=8)


def _random_packed(key, s: int):
    q = jax.random.randint(key, (s * SPEC.rows,), 0, 2**SPEC.cols, dtype=jnp.int32)
    return bitslice.section_planes_packed(q, SPEC.rows, SPEC.cols)


# ---------------------------------------------------------------------------
# injection + read
# ---------------------------------------------------------------------------

def test_read_packed_handcrafted():
    planes = jnp.asarray([[0b10110000], [0b01010000]], jnp.uint8)[None]  # [1,2,1]
    s0 = jnp.asarray([[0b10000000], [0b00000000]], jnp.uint8)[None]
    s1 = jnp.asarray([[0b00000001], [0b00010000]], jnp.uint8)[None]
    out = nonideal.read_packed(planes, s0, s1)
    np.testing.assert_array_equal(
        np.asarray(out), [[[0b00110001], [0b01010000]]]
    )


def test_inject_deterministic_and_disjoint():
    m = nonideal.FaultModel(stuck0=0.05, stuck1=0.05, hotspot_fraction=0.25)
    a = nonideal.inject(SPEC, 8, m, jax.random.PRNGKey(3))
    b = nonideal.inject(SPEC, 8, m, jax.random.PRNGKey(3))
    c = nonideal.inject(SPEC, 8, m, jax.random.PRNGKey(4))
    np.testing.assert_array_equal(np.asarray(a.stuck0), np.asarray(b.stuck0))
    np.testing.assert_array_equal(np.asarray(a.stuck1), np.asarray(b.stuck1))
    assert not np.array_equal(np.asarray(a.stuck0), np.asarray(c.stuck0))
    # a cell has one defect: stuck0 and stuck1 never overlap
    assert int(jnp.sum(a.stuck0 & a.stuck1)) == 0
    assert a.fault_cells().sum() > 0


def test_inject_padding_rows_fault_free():
    spec = CrossbarSpec(rows=12, cols=4)  # 12 rows pack into 2 bytes
    m = nonideal.FaultModel(stuck0=0.5, stuck1=0.5)
    st = nonideal.inject(spec, 4, m, jax.random.PRNGKey(0))
    bits = np.asarray(jnp.unpackbits(st.stuck0 | st.stuck1, axis=1))
    assert bits[:, 12:].sum() == 0  # padding rows carry no faults
    assert bits[:, :12].sum() > 0


def test_zero_rate_masks_zero_and_pool_read_identity():
    st = nonideal.inject(SPEC, 4, nonideal.FaultModel(), jax.random.PRNGKey(0))
    assert int(jnp.sum(st.stuck0)) == 0 and int(jnp.sum(st.stuck1)) == 0
    pool = CrossbarPool(SPEC, 4)
    pool.inject_faults(nonideal.FaultModel(), jax.random.PRNGKey(0))
    packed = _random_packed(jax.random.PRNGKey(1), 8)
    rep = pool.program(packed, schedule.make_chains(8, 4, "stride1"))
    # byte-identical planes: the non-ideal read at rate 0 IS the clean read
    np.testing.assert_array_equal(
        np.asarray(rep.achieved_read), np.asarray(rep.achieved)
    )
    np.testing.assert_array_equal(pool.read_state(), pool.state)


def test_hotspot_multiplier_concentrates_faults():
    m = nonideal.FaultModel(
        stuck0=0.005, stuck1=0.005, hotspot_fraction=0.5, hotspot_mult=16.0
    )
    st = nonideal.inject(SPEC, 16, m, jax.random.PRNGKey(9))
    cells = st.fault_cells()
    assert st.hot.any() and (~st.hot).any()
    assert cells[st.hot].mean() > 4 * cells[~st.hot].mean()


# ---------------------------------------------------------------------------
# fault-aware remapping
# ---------------------------------------------------------------------------

def test_fault_assignment_identity_without_faults():
    damage = np.zeros((4, 8), np.int64)
    np.testing.assert_array_equal(
        nonideal.fault_aware_assignment(damage), np.arange(4, dtype=np.int32)
    )


def test_fault_assignment_avoids_concentrated_faults():
    packed = _random_packed(jax.random.PRNGKey(2), 12)
    chains = schedule.make_chains(12, 3, "stride1")
    words = -(-SPEC.rows // 8)
    s0 = np.zeros((6, words, SPEC.cols), np.uint8)
    s1 = np.zeros_like(s0)
    s0[1] = 0xFF  # crossbar 1: every cell stuck at 0
    s1[4] = 0xFF  # crossbar 4: every cell stuck at 1
    st = nonideal.FaultState(
        model=nonideal.FaultModel(stuck0=1.0),
        stuck0=jnp.asarray(s0), stuck1=jnp.asarray(s1),
        hot=np.zeros(6, bool),
    )
    damage = nonideal.damage_matrix(packed, chains, st)
    assert damage.shape == (3, 6)
    assign = nonideal.fault_aware_assignment(damage)
    assert len(set(assign.tolist())) == 3  # distinct crossbars
    assert 1 not in assign and 4 not in assign


def test_fault_leveling_reduces_read_damage():
    """With hotspot faults and spare capacity, 'fault' leveling reads back
    strictly fewer flipped bits than the naive identity assignment."""
    m = nonideal.FaultModel(
        stuck0=0.02, stuck1=0.02, hotspot_fraction=0.4, hotspot_mult=16.0
    )
    packed = _random_packed(jax.random.PRNGKey(5), 16)
    chains = schedule.make_chains(16, 4, "stride1")
    flips = {}
    for leveling in ("none", "fault"):
        pool = CrossbarPool(SPEC, 8, leveling=leveling)
        pool.inject_faults(m, jax.random.PRNGKey(11))
        rep = pool.program(packed, chains)
        diff = jnp.unpackbits(rep.achieved ^ rep.achieved_read, axis=1)
        flips[leveling] = int(jnp.sum(diff.astype(jnp.int32)))
    assert flips["fault"] < flips["none"]


def test_fault_leveling_without_faults_falls_back_to_lpt():
    packed = _random_packed(jax.random.PRNGKey(6), 8)
    chains = schedule.make_chains(8, 4, "stride1")
    rep_f = CrossbarPool(SPEC, 4, leveling="fault").program(packed, chains)
    rep_l = CrossbarPool(SPEC, 4, leveling="lpt").program(packed, chains)
    np.testing.assert_array_equal(rep_f.assignment, rep_l.assignment)


def test_pool_spec_validation():
    with pytest.raises(ValueError):
        CrossbarPool(CrossbarSpec(rows=0, cols=8), 2)
    with pytest.raises(ValueError):
        CrossbarPool(CrossbarSpec(rows=64, cols=-1), 2)


@pytest.mark.parametrize(
    "kwargs, field",
    [
        (dict(stuck0=-0.1), "stuck0"),
        (dict(stuck0=1.5), "stuck0"),
        (dict(stuck1=2.0), "stuck1"),
        (dict(hotspot_fraction=-0.01), "hotspot_fraction"),
        (dict(hotspot_fraction=1.01), "hotspot_fraction"),
        (dict(drift_sigma=-0.5), "drift_sigma"),
        (dict(ir_alpha=-1.0), "ir_alpha"),
        (dict(hotspot_mult=-2.0), "hotspot_mult"),
    ],
)
def test_fault_model_rejects_invalid_rates(kwargs, field):
    """Construction is the single choke point: a bad rate never reaches
    pool.inject_faults or perturb_operands, and the error names the field."""
    with pytest.raises(ValueError, match=field):
        nonideal.FaultModel(**kwargs)


def test_fault_model_accepts_boundary_rates():
    nonideal.FaultModel(stuck0=0.0, stuck1=1.0, hotspot_fraction=1.0,
                        drift_sigma=0.0, ir_alpha=0.0, hotspot_mult=0.0)


# ---------------------------------------------------------------------------
# property tests (hypothesis; integer strategies → derived float rates)
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       sections=st.integers(min_value=1, max_value=12))
def test_prop_zero_rate_read_is_byte_identity(seed, sections):
    """Property: all-zero fault rates make the non-ideal read a bitwise
    identity on arbitrary packed planes."""
    st_f = nonideal.inject(SPEC, sections, nonideal.FaultModel(),
                           jax.random.PRNGKey(seed))
    assert int(jnp.sum(st_f.stuck0)) == 0 and int(jnp.sum(st_f.stuck1)) == 0
    planes = _random_packed(jax.random.PRNGKey(seed ^ 0x5A5A), sections)
    out = nonideal.read_packed(
        planes,
        st_f.stuck0[:sections].astype(jnp.uint8),
        st_f.stuck1[:sections].astype(jnp.uint8),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(planes))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       s0_pm=st.integers(min_value=0, max_value=500),
       s1_pm=st.integers(min_value=0, max_value=500),
       hot=st.booleans())
def test_prop_stuck_masks_disjoint(seed, s0_pm, s1_pm, hot):
    """Property: across arbitrary rates (permille-derived) and hotspot
    shapes, no cell is ever both stuck-at-0 and stuck-at-1."""
    m = nonideal.FaultModel(
        stuck0=s0_pm / 1000.0, stuck1=s1_pm / 1000.0,
        hotspot_fraction=0.5 if hot else 0.0,
        hotspot_mult=8.0 if hot else 1.0,
    )
    st_f = nonideal.inject(SPEC, 6, m, jax.random.PRNGKey(seed))
    assert int(jnp.sum(st_f.stuck0 & st_f.stuck1)) == 0


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       s_pm=st.integers(min_value=1, max_value=60),
       drift_cs=st.integers(min_value=0, max_value=10),
       ir_cs=st.integers(min_value=0, max_value=20))
def test_prop_perturb_operands_deterministic_under_fixed_key(
        seed, s_pm, drift_cs, ir_cs):
    """Property: perturb_operands is a pure function of (operands, model,
    key) — two applications under the same PRNG key compose to identical
    leaves, and the densified fold agrees between them."""
    m = nonideal.FaultModel(
        stuck0=s_pm / 1000.0, stuck1=s_pm / 1000.0,
        drift_sigma=drift_cs / 100.0, ir_alpha=ir_cs / 100.0,
    )
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 12)) * 0.05
    op = simulator.prepare_linear(w, CrossbarSpec(rows=16, cols=8),
                                  materialize="packed")
    key = jax.random.PRNGKey(seed)
    pa = nonideal.perturb_operands(op, m, key)
    pb = nonideal.perturb_operands(op, m, key)
    la, lb = jax.tree.leaves(pa), jax.tree.leaves(pb)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(simulator.densify_operands(pa)),
        np.asarray(simulator.densify_operands(pb)),
    )


# ---------------------------------------------------------------------------
# serving-side perturbation
# ---------------------------------------------------------------------------

def test_perturb_operands_ideal_is_same_object():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 12)) * 0.05
    op = simulator.prepare_linear(w, CrossbarSpec(rows=16, cols=8), materialize="packed")
    assert nonideal.perturb_operands(op, nonideal.FaultModel(), jax.random.PRNGKey(0)) is op


@pytest.mark.parametrize(
    "model",
    [
        nonideal.FaultModel(stuck0=0.03, stuck1=0.03),
        nonideal.FaultModel(drift_sigma=0.08),
        nonideal.FaultModel(ir_alpha=0.2),
        nonideal.FaultModel(stuck0=0.02, stuck1=0.02, drift_sigma=0.05, ir_alpha=0.1),
    ],
)
def test_perturbed_cim_linear_matches_densify(model):
    w = jax.random.normal(jax.random.PRNGKey(0), (48, 20)) * 0.05
    op = simulator.prepare_linear(w, CrossbarSpec(rows=16, cols=8), materialize="packed")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 48))
    pop = nonideal.perturb_operands(op, model, jax.random.PRNGKey(7))
    y_cim = simulator.cim_linear(x, pop)
    y_dense = x @ simulator.densify_operands(pop)
    np.testing.assert_allclose(np.asarray(y_cim), np.asarray(y_dense), atol=1e-5)
    # and the perturbation actually did something
    y_clean = simulator.cim_linear(x, op)
    assert float(jnp.max(jnp.abs(y_cim - y_clean))) > 0


def test_perturb_operands_rejects_int8_dicts():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 12)) * 0.05
    op = simulator.prepare_linear(w, CrossbarSpec(rows=16, cols=8), materialize="int8")
    with pytest.raises(ValueError):
        nonideal.perturb_operands(op, nonideal.FaultModel(stuck0=0.1), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# zero-fault parity end to end (deployment + engine)
# ---------------------------------------------------------------------------

LM_SPEC = CrossbarSpec(rows=128, cols=10)
LM_CFG = PlannerConfig(p_stuck=0.5, min_size=1024)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _deploy(params, *, faulted: bool, materialize: str):
    pool = CrossbarPool(LM_SPEC, LM_CFG.crossbars)
    if faulted:
        pool.inject_faults(nonideal.FaultModel(), jax.random.PRNGKey(5))
    plan = build_deployment(params, LM_SPEC, LM_CFG, pool=pool)
    return deploy_params(params, plan, materialize=materialize)


@pytest.mark.parametrize("materialize", MATERIALIZATIONS)
def test_zero_fault_deployment_byte_identical(gemma, materialize):
    """Fault rate 0.0 leaves every deployed leaf — packed planes included —
    byte-identical to the clean deployment, for all materializations."""
    cfg, params = gemma
    clean = _deploy(params, faulted=False, materialize=materialize)
    zero = _deploy(params, faulted=True, materialize=materialize)
    la, lb = jax.tree.leaves(clean), jax.tree.leaves(zero)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_fault_engine_stream_bit_identical(gemma):
    """Engine token streams from a zero-fault-injected packed deployment are
    bit-identical to solo generation on the clean deployment."""
    cfg, params = gemma
    clean = _deploy(params, faulted=False, materialize="packed")
    zero = _deploy(params, faulted=True, materialize="packed")
    specs = [(11, 5, True, 0), (7, 6, False, 3)]
    reqs = []
    for rid, (plen, gen, greedy, seed) in enumerate(specs):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
        )
        reqs.append(Request(rid=rid, prompt=prompt, max_new_tokens=gen,
                            greedy=greedy, seed=seed))
    eng = Engine(
        cfg, zero,
        EngineConfig(max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=8,
                     decode_quantum=4),
    )
    results = eng.run(reqs)
    for req, res in zip(reqs, results):
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        toks, _ = generate(cfg, clean, batch, gen_len=req.max_new_tokens,
                           greedy=req.greedy, seed=req.seed)
        assert res.tokens == [int(t) for t in np.asarray(toks[0])], f"rid {req.rid}"
