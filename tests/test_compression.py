"""Int8 gradient compression with error feedback."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import compression as comp


def test_roundtrip_error_bounded(key):
    g = {"a": jax.random.normal(key, (64, 32)), "b": jax.random.normal(key, (10,))}
    err0 = comp.init_error_state(g)
    g_hat, err = comp.compress_decompress(g, err0)
    for name in g:
        amax = float(jnp.max(jnp.abs(g[name])))
        step = amax / 127.0
        assert float(jnp.max(jnp.abs(g[name] - g_hat[name]))) <= step * 0.5 + 1e-7
        # residual is exactly the roundtrip error
        np.testing.assert_allclose(err[name], g[name] - g_hat[name], rtol=1e-6, atol=1e-7)


def test_error_feedback_unbiased_over_time(key):
    """With a constant gradient, error feedback makes the *cumulative* applied
    update converge to the cumulative true gradient (EF-SGD guarantee)."""
    g = {"w": jax.random.normal(key, (32, 32)) * 1e-3}
    err = comp.init_error_state(g)
    applied = jnp.zeros_like(g["w"])
    steps = 50
    for _ in range(steps):
        g_hat, err = comp.compress_decompress(g, err)
        applied = applied + g_hat["w"]
    true_sum = g["w"] * steps
    # relative deviation of cumulative updates shrinks to the residual bound
    rel = float(jnp.linalg.norm(applied - true_sum) / jnp.linalg.norm(true_sum))
    assert rel < 0.02


def test_wire_format_is_int8(key):
    g = {"w": jax.random.normal(key, (16, 16))}
    q, s, _ = comp.compress(g, comp.init_error_state(g))
    assert q["w"].dtype == jnp.int8  # 4x narrower than f32 on the wire
    assert s["w"].dtype == jnp.float32 and s["w"].shape == ()


def test_training_parity_tiny_model(key):
    """Compressed-gradient training tracks uncompressed on a least-squares
    toy problem (loss gap < 10%)."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (128, 8))
    w_true = jax.random.normal(k2, (8, 1))
    y = x @ w_true

    def loss(w):
        return jnp.mean((x @ w - y) ** 2)

    grad = jax.grad(loss)
    lr = 0.05

    w_plain = jnp.zeros((8, 1))
    w_comp = jnp.zeros((8, 1))
    err = comp.init_error_state({"w": w_comp})
    for _ in range(100):
        w_plain = w_plain - lr * grad(w_plain)
        g_hat, err = comp.compress_decompress({"w": grad(w_comp)}, err)
        w_comp = w_comp - lr * g_hat["w"]
    lp, lc = float(loss(w_plain)), float(loss(w_comp))
    assert lc < 1e-3 or lc <= lp * 1.1
