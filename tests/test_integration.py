"""Integration: train -> checkpoint -> deploy to crossbars -> serve.

The full product loop on a reduced model: trains a small LM until the loss
drops, deploys the trained weights to simulated crossbars with SWS +
bit stucking, and asserts (a) the reprogramming speedup is real and (b) the
deployed model's predictions agree with the trained model (the paper's
accuracy-preservation constraint) — then serves both through prefill/decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.planner import CrossbarSpec, PlannerConfig, build_deployment, deploy_params
from repro.data import DataConfig, make_dataset
from repro.launch.serve import generate
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import FaultPolicy, TrainLoop, TrainLoopConfig

# trains a model end-to-end: minutes of wall clock -> out of tier-1
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def trained():
    cfg = get_arch("internlm2-1.8b", reduced=True)
    steps = 30
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=steps)))
    ds = make_dataset(DataConfig(cfg.vocab_size, 32, 4, task="copy"))
    params = api.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    losses = []
    for s in range(steps):
        params, opt, m = step_fn(params, opt, ds.batch_at(s))
        losses.append(float(m["loss"]))
    return cfg, params, losses


def test_training_reduces_loss(trained):
    _, _, losses = trained
    assert losses[-1] < losses[0] * 0.8


def test_deploy_trained_model_preserves_predictions(trained):
    cfg, params, _ = trained
    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10),
        PlannerConfig(p_stuck=0.5, min_size=1024),
    )
    t = plan.totals()
    assert t["sws_speedup"] > 1.0
    assert t["total_speedup"] > t["sws_speedup"]

    params_hat = deploy_params(params, plan)
    batch = api.make_batch(cfg, jax.random.PRNGKey(3), 2, 32)
    la, _ = api.forward(params, cfg, batch)
    lb, _ = api.forward(params_hat, cfg, batch)
    agree = float(jnp.mean((jnp.argmax(la, -1) == jnp.argmax(lb, -1)).astype(jnp.float32)))
    assert agree >= 0.99


def test_serve_trained_and_deployed(trained):
    cfg, params, _ = trained
    batch = api.make_batch(cfg, jax.random.PRNGKey(4), 2, 16)
    toks, tps = generate(cfg, params, batch, gen_len=8)
    assert toks.shape == (2, 8) and tps > 0

    plan = build_deployment(
        params, CrossbarSpec(rows=128, cols=10), PlannerConfig(p_stuck=0.5, min_size=1024)
    )
    toks_hat, _ = generate(cfg, deploy_params(params, plan), batch, gen_len=8)
    # greedy decode of a trained model should be nearly identical
    agree = float(jnp.mean((toks == toks_hat).astype(jnp.float32)))
    assert agree >= 0.75
