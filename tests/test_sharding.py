"""Sharding rules: every param of every arch resolves to a divisible spec
for the production 16x16 / 2x16x16 meshes (tested via the rule resolver
directly — the dry-run sweep exercises the real meshes with 512 devices)."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import api
from repro.parallel.sharding import _path_name, _resolve

AXIS_SIZES = {"data": 16, "model": 16}
AXIS_SIZES_POD = {"pod": 2, "data": 16, "model": 16}


def _param_shapes(arch: str):
    cfg = get_arch(arch)
    specs = jax.eval_shape(
        lambda k: api.init(k, cfg), jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    )
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    return [(_path_name(p), tuple(l.shape)) for p, l in flat]


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("axis_sizes", [AXIS_SIZES, AXIS_SIZES_POD], ids=["single", "multi"])
def test_all_params_resolve_divisibly(arch, axis_sizes):
    for name, shape in _param_shapes(arch):
        spec = _resolve(name, shape, axis_sizes, fsdp=False, fsdp_min=2**16)
        flat_spec = list(spec)
        assert len(flat_spec) == len(shape), (name, shape, spec)
        for dim, ax in zip(shape, flat_spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            k = int(np.prod([axis_sizes[a] for a in axes]))
            assert dim % k == 0, f"{arch} {name} {shape} spec {spec} not divisible"


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-236b", "gemma-2b"])
def test_fsdp_shards_more_dims(arch):
    sharded_plain, sharded_fsdp = 0, 0
    for name, shape in _param_shapes(arch):
        sp = _resolve(name, shape, AXIS_SIZES, fsdp=False, fsdp_min=2**16)
        sf = _resolve(name, shape, AXIS_SIZES, fsdp=True, fsdp_min=2**16)
        sharded_plain += sum(a is not None for a in sp)
        sharded_fsdp += sum(a is not None for a in sf)
        # fsdp only adds sharding, never removes
        for a, b in zip(sp, sf):
            if a is not None:
                assert b == a
    assert sharded_fsdp > sharded_plain


def test_big_weights_are_model_sharded():
    """No >=2-D weight above 1M elements may be fully replicated (TP sanity)."""
    for arch in list_archs():
        for name, shape in _param_shapes(arch):
            if len(shape) < 2 or int(np.prod(shape)) < 2**20:
                continue
            spec = _resolve(name, shape, AXIS_SIZES, fsdp=False, fsdp_min=2**16)
            assert any(a is not None for a in spec), (
                f"{arch}: large param {name} {shape} is fully replicated"
            )


def test_moe_expert_parallel_everywhere():
    # deepseek: 160 experts % 16 == 0 -> expert-parallel on dim 0
    ds = [s for n, s in _param_shapes("deepseek-v2-236b") if n.endswith("moe/wi_gate")]
    spec = _resolve("segments/0/moe/wi_gate", ds[0], AXIS_SIZES, fsdp=False, fsdp_min=1)
    assert spec[1] == "model"  # (layer-stacked) expert dim sharded
    # qwen: 60 routed experts padded to 64 (MoEConfig.pad_experts_to) so EP
    # applies instead of the expert-TP fallback (§Perf iteration 2: the TP
    # path psums a 10.7 GB dispatch-buffer cotangent per layer)
    qw = [s for n, s in _param_shapes("qwen2-moe-a2.7b") if n.endswith("moe/wi_gate")]
    assert qw[0][1] == 64  # padded expert dim
    spec = _resolve("segments/0/moe/wi_gate", qw[0], AXIS_SIZES, fsdp=False, fsdp_min=1)
    assert spec[1] == "model"


def test_moe_tp_fallback_rule_still_works():
    # a hypothetical 60-expert tensor without padding falls back to expert-ffn TP
    spec = _resolve("segments/0/moe/wi_gate", (24, 60, 2048, 1408), AXIS_SIZES,
                    fsdp=False, fsdp_min=1)
    assert spec[1] is None and spec[3] == "model"


def test_gemma_mqa_kv_replicated_q_sharded():
    shapes = dict(_param_shapes("gemma-2b"))
    wq = shapes["segments/0/attn/wq"]
    wk = shapes["segments/0/attn/wk"]
    sq = _resolve("segments/0/attn/wq", wq, AXIS_SIZES, fsdp=False, fsdp_min=1)
    sk = _resolve("segments/0/attn/wk", wk, AXIS_SIZES, fsdp=False, fsdp_min=1)
    assert sq[2] == "model"  # 8 heads * 256 hd = 2048 % 16 == 0 via fused dim
    assert sk[2] == "model" or sk[2] is None  # kv=1 head: 256 % 16 == 0 -> shards
