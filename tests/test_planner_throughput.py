"""Packed fast-path vs bool reference: whole-plan bit-exactness + benchmark.

The fast parity test is the tier-1 guarantee behind the throughput numbers:
``impl="packed"`` and ``impl="bool"`` must produce the SAME DeploymentPlan —
transitions, lockstep times, and achieved weights — for every config knob.
The end-to-end speedup measurement itself is marked slow (it times tens of
seconds of both implementations) and runs via ``-m slow`` or
``python -m benchmarks.planner_throughput``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.planner import CrossbarSpec, PlannerConfig, analyze_tensor, build_deployment


def _plans_equal(pa, pb) -> bool:
    if set(pa.reports) != set(pb.reports):
        return False
    for k, ra in pa.reports.items():
        rb = pb.reports[k]
        if (
            ra.transitions_baseline != rb.transitions_baseline
            or ra.transitions_sws != rb.transitions_sws
            or ra.transitions_final != rb.transitions_final
            or ra.lockstep_time_unsorted != rb.lockstep_time_unsorted
            or ra.lockstep_time_greedy != rb.lockstep_time_greedy
            or ra.lockstep_time_ideal != rb.lockstep_time_ideal
            or ra.quant_mse != rb.quant_mse
        ):
            return False
        if not bool(jnp.all(pa.deployed[k] == pb.deployed[k])):
            return False
    return True


@pytest.mark.parametrize("p_stuck", [1.0, 0.5])
@pytest.mark.parametrize("kind", ["stride1", "strideL"])
def test_packed_plan_bit_exact_vs_bool(key, p_stuck, kind):
    params = {
        "a": {"w": jax.random.normal(key, (96, 64)) * 0.02},
        "b": {"w": jax.random.normal(jax.random.PRNGKey(3), (64, 80)) * 0.02},
    }
    spec = CrossbarSpec(rows=64, cols=8)
    mk = lambda impl: PlannerConfig(
        p_stuck=p_stuck, schedule=kind, min_size=1024, impl=impl
    )
    plan_p = build_deployment(params, spec, mk("packed"))
    plan_b = build_deployment(params, spec, mk("bool"))
    assert _plans_equal(plan_p, plan_b)


@pytest.mark.parametrize("encoding", ["sign_magnitude", "offset_binary"])
def test_packed_bit_exact_across_encodings(key, encoding):
    w = jax.random.normal(key, (128, 72)) * 0.03 + 0.01
    spec = CrossbarSpec(rows=128, cols=10, encoding=encoding)
    rp, wp = analyze_tensor(w, spec, PlannerConfig(p_stuck=0.5), key)
    rb, wb = analyze_tensor(w, spec, PlannerConfig(p_stuck=0.5, impl="bool"), key)
    assert rp.transitions_baseline == rb.transitions_baseline
    assert rp.transitions_sws == rb.transitions_sws
    assert rp.transitions_final == rb.transitions_final
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wb))


def test_shape_bucketed_jit_reuses_traces(key):
    """Same-shape tensors must not retrace the jitted per-tensor core."""
    from repro.core.planner import _analyze_core

    spec = CrossbarSpec(rows=64, cols=8)
    cfg = PlannerConfig(min_size=1024)
    before = _analyze_core._cache_size()
    for i in range(4):
        w = jax.random.normal(jax.random.PRNGKey(i), (64, 96)) * 0.02
        analyze_tensor(w, spec, cfg, jax.random.PRNGKey(i))
    assert _analyze_core._cache_size() - before <= 1


def test_totals_aggregate_in_int64(monkeypatch, key):
    """Whole-tensor totals must not wrap int32: aggregation happens on the
    host in int64 from per-job / per-chain int32 values."""
    from repro.core import planner as planner_mod

    w = jax.random.normal(key, (128, 64)) * 0.02
    spec = CrossbarSpec(rows=64, cols=8)
    real_core = planner_mod._analyze_core

    def inflated_core(flat, key, spec, config):
        metrics, aux = real_core(flat, key, spec, config)
        # simulate an extreme-scale tensor: 64 jobs of 2^27 transitions each
        # (sum 2^33, far past int32) — only the aggregation path is under test
        metrics = dict(metrics)
        metrics["jobs_u"] = jnp.full((64,), 2**27, jnp.int32)
        metrics["jobs_s"] = jnp.full((64,), 2**27, jnp.int32)
        return metrics, aux

    monkeypatch.setattr(planner_mod, "_analyze_core", inflated_core)
    rep, _ = planner_mod.analyze_tensor(w, spec, PlannerConfig(), key)
    assert rep.transitions_baseline == 64 * 2**27  # 2^33 > int32 max
    assert rep.transitions_sws == 64 * 2**27
    assert rep.lockstep_time_greedy == 2**27  # one round of 64 equal jobs
    assert rep.lockstep_time_ideal == 2**33 / 64


def test_unknown_impl_rejected(key):
    w = jnp.ones((64, 64))
    with pytest.raises(ValueError, match="unknown planner impl"):
        analyze_tensor(w, CrossbarSpec(rows=64, cols=8), PlannerConfig(impl="turbo"), key)


@pytest.mark.slow
def test_planner_throughput_benchmark_speedup():
    """Acceptance: packed path >= 3x over the seed bool path at LM scale,
    bit-exact.  Runs the real benchmark entry point (smaller workload)."""
    from benchmarks.planner_throughput import run

    r = run(max_elems=500_000, layers=4)
    assert r["bit_exact"]
    assert r["speedup"] >= 3.0, r
