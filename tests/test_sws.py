"""Tests for Sorted Weight Sectioning — the paper's §III.A claim."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import bitslice, cost, sws


def test_permutation_sorts_by_magnitude(key):
    w = jax.random.normal(key, (1000,))
    perm = sws.sws_permutation(w)
    sorted_abs = jnp.abs(w)[perm]
    assert bool(jnp.all(sorted_abs[1:] >= sorted_abs[:-1]))


@given(n=st.integers(2, 300))
def test_inverse_permutation(n):
    rng = np.random.default_rng(n)
    perm = jnp.asarray(rng.permutation(n), jnp.int32)
    inv = sws.inverse_permutation(perm)
    np.testing.assert_array_equal(perm[inv], jnp.arange(n))
    np.testing.assert_array_equal(inv[perm], jnp.arange(n))


@given(n=st.integers(1, 500), rows=st.sampled_from([16, 128]))
def test_restore_flat_roundtrip(n, rows):
    rng = np.random.default_rng(n)
    flat = jnp.asarray(rng.normal(size=n), jnp.float32)
    sections, perm, n_out = sws.sorted_sections(flat, rows)
    np.testing.assert_allclose(sws.restore_flat(sections, perm, n_out), flat, rtol=1e-6)


def test_sws_reduces_chain_transitions(key):
    """The core paper claim: sorted section order needs fewer transitions than
    the natural (unsorted/ISAAC-style) order, for bell-shaped weights."""
    rows, cols = 128, 10
    w = jax.random.normal(key, (rows * 256,)) * 0.02
    qt = bitslice.quantize(w, cols)

    planes_u = bitslice.bitplanes(qt.q.reshape(-1, rows), cols)
    perm = sws.sws_permutation(w)
    planes_s = bitslice.bitplanes(qt.q[perm].reshape(-1, rows), cols)

    t_unsorted = int(cost.chain_transitions(planes_u))
    t_sorted = int(cost.chain_transitions(planes_s))
    assert t_sorted < t_unsorted
    # paper Fig. 5 band: 1.4x - 1.9x for real DNN tensors; gaussian synthetic
    # falls in the same regime
    assert t_unsorted / t_sorted > 1.2


def test_sws_direction_irrelevant(key):
    rows, cols = 64, 8
    w = jax.random.normal(key, (rows * 64,)) * 0.02
    qt = bitslice.quantize(w, cols)
    up = sws.sws_permutation(w)
    down = sws.sws_permutation(w, descending=True)
    pu = bitslice.bitplanes(qt.q[up].reshape(-1, rows), cols)
    pd = bitslice.bitplanes(qt.q[down].reshape(-1, rows), cols)
    # without the initial pristine program, a reversed chain has equal cost
    a = int(cost.chain_transitions(pu, include_initial=False))
    b = int(cost.chain_transitions(pd, include_initial=False))
    # descending reverses element order but also reverses section *contents*
    # (sections are re-chunked), so costs differ slightly; they must be close.
    assert abs(a - b) / max(a, 1) < 0.1


def test_tsp_greedy_is_valid_order_and_not_worse(key):
    rows, cols = 32, 8
    w = jax.random.normal(key, (rows * 40,)) * 0.02
    qt = bitslice.quantize(w, cols)
    perm = sws.sws_permutation(w)
    planes = bitslice.bitplanes(qt.q[perm].reshape(-1, rows), cols)
    packed = bitslice.pack_rows(planes)

    order = sws.tsp_greedy_order(packed)
    np.testing.assert_array_equal(np.sort(np.asarray(order)), np.arange(planes.shape[0]))

    t_mag = int(cost.chain_transitions(planes, include_initial=False))
    t_tsp = int(cost.chain_transitions(planes, order, include_initial=False))
    # nearest-neighbour on true Hamming distance should beat (or match) the
    # magnitude-order proxy it greedily optimizes
    assert t_tsp <= t_mag * 1.02


def test_section_norm_order_sorts_sections(key):
    sections = jax.random.normal(key, (10, 16))
    order = sws.section_norm_order(sections)
    means = jnp.mean(jnp.abs(sections), axis=-1)[order]
    assert bool(jnp.all(means[1:] >= means[:-1]))


# ---------------------------------------------------------------------------
# host-callback sort routing (single-core deadlock guard)
# ---------------------------------------------------------------------------

def test_usable_cores_respects_affinity_mask(monkeypatch):
    """The guard counts cores THIS process may run on, not the whole box."""
    import os

    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {0}, raising=False)
    assert sws._usable_cores() == 1
    monkeypatch.setattr(os, "sched_getaffinity", lambda pid: {0, 1, 5}, raising=False)
    assert sws._usable_cores() == 3

    def boom(pid):
        raise OSError("no affinity syscall")

    monkeypatch.setattr(os, "sched_getaffinity", boom, raising=False)
    monkeypatch.setattr(os, "cpu_count", lambda: 4)
    assert sws._usable_cores() == 4


def test_use_host_sort_keys_on_cores_not_devices(monkeypatch):
    """Regression: the routing guard must be independent of
    ``jax.device_count()`` — emulated host-platform devices
    (``--xla_force_host_platform_device_count``) add execution streams
    without adding the second core the pending pure_callback needs, so a
    pinned single-core process must take the device sort no matter how many
    devices jax reports (the subprocess test in tests/test_tp_shard.py pins
    the full emulated-mesh run)."""
    monkeypatch.setattr(sws, "_usable_cores", lambda: 1)
    monkeypatch.setattr(jax, "device_count", lambda: 64, raising=False)
    assert sws._use_host_sort() is False
    monkeypatch.setattr(sws, "_usable_cores", lambda: 2)
    assert sws._use_host_sort() == (jax.default_backend() == "cpu")


def test_stable_argsort_same_permutation_on_both_routes(monkeypatch):
    """The two routes are interchangeable: forcing the device route yields
    the exact permutation (and inverse) of the host-callback route."""
    keys = jax.random.normal(jax.random.PRNGKey(7), (4096,))
    monkeypatch.setattr(sws, "_use_host_sort", lambda: False)
    dev_perm, dev_inv = sws.stable_argsort(keys, with_inverse=True)
    monkeypatch.undo()
    if sws._use_host_sort():
        host_perm, host_inv = sws.stable_argsort(keys, with_inverse=True)
        np.testing.assert_array_equal(np.asarray(host_perm), np.asarray(dev_perm))
        np.testing.assert_array_equal(np.asarray(host_inv), np.asarray(dev_inv))
    np.testing.assert_array_equal(
        np.asarray(dev_perm), np.argsort(np.asarray(keys), kind="stable")
    )
    np.testing.assert_array_equal(
        np.asarray(dev_inv)[np.asarray(dev_perm)], np.arange(4096)
    )
