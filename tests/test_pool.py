"""Tests for the persistent CrossbarPool: cross-tensor seams, wear, leveling.

Pins the three pool parity invariants:

(a) resetting the pool between tensors reproduces the stateless planner's
    per-tensor ``transitions_*`` totals bit-exactly (packed and bool impls);
(b) wear conservation — per-cell wear increments sum exactly to the
    programmed transitions, cross-tensor seams included;
(c) the packed fast path and the eager bool-oracle twin agree on every
    output (job costs, wear, state, achieved weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitslice, cost, schedule
from repro.core.planner import (
    CrossbarSpec,
    PlannerConfig,
    analyze_tensor,
    build_deployment,
    iter_weights,
)
from repro.core.pool import CrossbarPool

SPEC = CrossbarSpec(rows=64, cols=8)


def _params():
    return {
        "a": {"w": jax.random.normal(jax.random.PRNGKey(0), (96, 64)) * 0.02},
        # deliberately row-padded: 64*100 = 6400 -> 100 sections of 64
        "b": {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 100)) * 0.02},
    }


def _random_packed(key, s: int):
    q = jax.random.randint(key, (s * SPEC.rows,), 0, 2**SPEC.cols, dtype=jnp.int32)
    return bitslice.section_planes_packed(q, SPEC.rows, SPEC.cols)


@pytest.mark.parametrize("impl", ["packed", "bool"])
@pytest.mark.parametrize("p_stuck", [1.0, 0.5])
def test_pool_reset_parity(impl, p_stuck):
    """(a) pool reset between tensors == stateless per-tensor accounting."""
    cfg = PlannerConfig(p_stuck=p_stuck, min_size=1024, crossbars=8, impl=impl)
    params = _params()
    plan_ref = build_deployment(params, SPEC, cfg)
    pool = CrossbarPool(SPEC, cfg.crossbars)
    key = jax.random.PRNGKey(cfg.seed)
    seen = 0
    for name, w in iter_weights(params, cfg):
        key, sub = jax.random.split(key)
        pool.reset()
        rep, w_hat = analyze_tensor(w, SPEC, cfg, sub, name=name, pool=pool)
        ref = plan_ref.reports[name]
        assert rep.transitions_baseline == ref.transitions_baseline
        assert rep.transitions_sws == ref.transitions_sws
        assert rep.transitions_final == ref.transitions_final
        assert rep.lockstep_time_unsorted == ref.lockstep_time_unsorted
        assert rep.lockstep_time_greedy == ref.lockstep_time_greedy
        assert bool(jnp.all(w_hat == plan_ref.deployed[name]))
        seen += 1
    assert seen == 2


@pytest.mark.parametrize("p_stuck", [1.0, 0.5])
def test_pool_packed_bool_twin_bit_exact(p_stuck):
    """(c) persistent streaming (no resets): packed == bool oracle everywhere."""
    params = _params()
    outs = {}
    for impl in ("packed", "bool"):
        cfg = PlannerConfig(p_stuck=p_stuck, min_size=1024, crossbars=8, impl=impl)
        pool = CrossbarPool(SPEC, 8)
        plan = build_deployment(params, SPEC, cfg, pool=pool)
        outs[impl] = (plan, pool)
    (plan_p, pool_p), (plan_b, pool_b) = outs["packed"], outs["bool"]
    assert set(plan_p.reports) == set(plan_b.reports)
    for name in plan_p.reports:
        assert plan_p.reports[name].transitions_sws == plan_b.reports[name].transitions_sws
        assert plan_p.reports[name].transitions_final == plan_b.reports[name].transitions_final
        assert bool(jnp.all(plan_p.deployed[name] == plan_b.deployed[name]))
    np.testing.assert_array_equal(pool_p.wear, pool_b.wear)
    np.testing.assert_array_equal(pool_p.state, pool_b.state)
    assert pool_p.total_writes == pool_b.total_writes


@pytest.mark.parametrize("p_stuck", [1.0, 0.5])
def test_pool_wear_conservation(p_stuck):
    """(b) sum of wear increments == sum of transitions_final, seams included."""
    cfg = PlannerConfig(p_stuck=p_stuck, min_size=1024, crossbars=8)
    pool = CrossbarPool(SPEC, 8)
    plan = build_deployment(_params(), SPEC, cfg, pool=pool)
    fin = sum(r.transitions_final for r in plan.reports.values())
    assert pool.total_writes == fin
    assert int(pool.wear.sum()) == fin
    assert plan.pool_stats is not None
    assert plan.pool_stats["total_writes"] == fin
    assert plan.pool_stats["max_cell_writes"] == int(pool.wear.max())


def test_pool_seam_pricing_from_persistent_state(key):
    """Seams of the second tensor are priced against the first tensor's
    leftover content, exactly as a manual XOR-popcount says."""
    k1, k2 = jax.random.split(key)
    packed1, packed2 = _random_packed(k1, 12), _random_packed(k2, 12)
    chains = schedule.make_chains(12, 4, "stride1")
    pool = CrossbarPool(SPEC, 4)

    rep1 = pool.program(packed1, chains)
    # a pristine pool's seam IS the include_initial first-program cost
    firsts = np.array([c[0] for c in chains])
    np.testing.assert_array_equal(
        rep1.seam_costs,
        np.asarray(
            cost.pair_transitions_packed(jnp.zeros_like(packed1[firsts]), packed1[firsts])
        ),
    )

    state_before = jnp.asarray(pool.state)
    rep2 = pool.program(packed2, chains)
    expected = cost.pair_transitions_packed(
        state_before[rep2.assignment], packed2[firsts]
    )
    np.testing.assert_array_equal(rep2.seam_costs, np.asarray(expected))
    assert rep2.transitions_full == int(rep2.job_costs.sum())
    assert rep2.transitions_programmed == rep2.transitions_full  # p=1


def test_pool_final_state_is_last_section():
    """After a full-reprogram walk each crossbar holds its chain's last section."""
    packed = _random_packed(jax.random.PRNGKey(3), 8)
    chains = schedule.make_chains(8, 4, "stride1")
    pool = CrossbarPool(SPEC, 4)
    rep = pool.program(packed, chains)
    for i, c in enumerate(chains):
        np.testing.assert_array_equal(
            pool.state[rep.assignment[i]], np.asarray(packed[int(c[-1])])
        )


def test_pool_lpt_leveling_reduces_max_cell_wear():
    """Acceptance: LPT leveling beats the naive identity assignment on
    max-cell wear for a stream of SWS-sorted tensors (whose chain costs are
    persistently skewed — the last chain always holds the largest weights)."""
    params = {
        f"l{i}": {"w": jax.random.normal(jax.random.PRNGKey(i), (128, 96)) * 0.02}
        for i in range(6)
    }
    wear_max = {}
    for leveling in ("none", "lpt"):
        cfg = PlannerConfig(p_stuck=1.0, min_size=1024, crossbars=8, pool_leveling=leveling)
        pool = CrossbarPool(SPEC, 8, leveling=leveling)
        build_deployment(params, SPEC, cfg, pool=pool)
        wear_max[leveling] = pool.stats().max_cell_writes
        per_xbar = pool.wear_totals()
        if leveling == "lpt":
            assert per_xbar.max() / per_xbar.mean() < 1.2  # balanced
    assert wear_max["lpt"] < wear_max["none"]


def test_pool_lpt_assignment_targets_least_worn():
    """Heaviest chain lands on the least-worn crossbar; assignment is a
    permutation (distinct physical crossbars)."""
    packed = _random_packed(jax.random.PRNGKey(5), 8)
    pool = CrossbarPool(SPEC, 4, leveling="lpt")
    # pre-skew wear: crossbar 2 pristine, others heavily worn
    pool.wear[0] += 1000
    pool.wear[1] += 800
    pool.wear[3] += 600
    chains = schedule.make_chains(8, 4, "stride1")
    rep = pool.program(packed, chains)
    assert sorted(rep.assignment.tolist()) == [0, 1, 2, 3]
    intra = rep.chain_totals - rep.seam_costs
    assert rep.assignment[int(np.argmax(intra))] == 2


def test_pool_rotate_leveling_spreads_small_tensors():
    """With fewer chains than crossbars, rotation seeds at the least-worn
    crossbar, so repeated small tensors spread over the whole pool."""
    pool = CrossbarPool(SPEC, 8, leveling="rotate")
    chains = schedule.make_chains(4, 4, "stride1")
    used = set()
    for i in range(4):
        packed = _random_packed(jax.random.PRNGKey(10 + i), 4)
        rep = pool.program(packed, chains)
        used.update(rep.assignment.tolist())
    assert used == set(range(8))


def test_pool_validation():
    pool = CrossbarPool(SPEC, 2)
    packed = _random_packed(jax.random.PRNGKey(0), 6)
    with pytest.raises(ValueError):  # more chains than crossbars
        pool.program(packed, schedule.make_chains(6, 3, "stride1"))
    with pytest.raises(ValueError):  # wrong geometry
        CrossbarPool(CrossbarSpec(rows=128, cols=10), 2).program(
            packed, schedule.make_chains(6, 2, "stride1")
        )
    with pytest.raises(ValueError):
        CrossbarPool(SPEC, 2, leveling="wearless")
    with pytest.raises(ValueError):  # pools price physical seams
        analyze_tensor(
            jnp.zeros((64, 64)),
            SPEC,
            PlannerConfig(include_initial=False),
            jax.random.PRNGKey(0),
            pool=pool,
        )


def test_pool_reset_keeps_wear_by_default():
    packed = _random_packed(jax.random.PRNGKey(1), 6)
    pool = CrossbarPool(SPEC, 3)
    pool.program(packed, schedule.make_chains(6, 3, "stride1"))
    assert pool.total_writes > 0
    pool.reset()
    assert np.all(pool.state == 0) and pool.total_writes > 0
    pool.reset(wear=True)
    assert pool.total_writes == 0 and int(pool.wear.sum()) == 0
