"""Fault-tolerant serving fleet: routing, failover, hedging, admission.

The fleet-level acceptance contract extends the engine's: every request a
:class:`Fleet` *completes* — through crashes, stalls, hedged duplicate
dispatches, operator kills/drains/restores, and corrupted health probes —
emits a token stream bit-identical to running it alone through
``launch.serve.generate`` with the same PRNG seed.  Chaos routes requests
around; it never changes their tokens.  Requests the fleet does NOT
complete fail loudly and cheaply: deadline expiry retires as ``"timeout"``
with partial tokens, admission overflow as ``"shed"``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.engine import EngineConfig, HealthConfig, HealthMonitor, Request
from repro.launch.fleet import (
    ChaosEvent,
    FaultInjector,
    Fleet,
    FleetConfig,
    FleetResult,
)
from repro.launch.mesh import replica_devices
from repro.launch.serve import generate
from repro.models import api
from repro.runtime.fault import FaultPolicy

ECFG = EngineConfig(
    max_slots=2, page_size=8, max_seq_len=64, prefill_chunk=16, decode_quantum=4
)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_arch("gemma-2b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, rid, plen, gen, seed=0, greedy=False, **kw):
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0, cfg.vocab_size)
    )
    return Request(rid=rid, prompt=prompt, max_new_tokens=gen, greedy=greedy,
                   seed=seed, **kw)


def _solo(cfg, params, req):
    batch = {"tokens": jnp.asarray(req.prompt)[None]}
    toks, _ = generate(cfg, params, batch, gen_len=req.max_new_tokens,
                       greedy=req.greedy, seed=req.seed)
    return [int(t) for t in np.asarray(toks[0])]


def _assert_parity(cfg, params, fleet, reqs, results):
    for req, res in zip(reqs, results):
        assert res.status == "ok", (req.rid, res)
        # degraded mode may have clamped max_new_tokens: compare against the
        # request as the fleet actually admitted it
        eff = fleet.requests[req.rid]
        assert res.tokens == _solo(cfg, params, eff), f"rid {req.rid}"


# ---------------------------------------------------------------------------
# Config + injector basics
# ---------------------------------------------------------------------------

def test_fleet_config_validation():
    for bad in (
        dict(n_replicas=0),
        dict(max_queue=0),
        dict(failover="panic"),
        dict(hedge_stall_s=0.0),
        dict(hedge_after_marks=0),
    ):
        with pytest.raises(ValueError):
            FleetConfig(**bad)
    assert FleetConfig(max_queue=10).degrade_at == 5
    assert FleetConfig(max_queue=10, degrade_backlog=8).degrade_at == 8


def test_fault_injector_fires_once_per_event_and_logs():
    inj = FaultInjector()
    inj.crash(0, at_step=2, lose_state=True)
    inj.stall(1, at_step=0, duration_s=1.0)
    assert inj.fire(0, 0, now=0.0) == []  # not yet reached
    assert inj.fire(1, 0, now=0.0)[0].kind == "stall"
    fired = inj.fire(0, 5, now=1.0)  # past at_step still fires (once)
    assert fired[0].kind == "crash" and fired[0].lose_state
    assert inj.fire(0, 6, now=2.0) == []  # never re-fires
    assert [e["kind"] for e in inj.log] == ["stall", "crash"]


def test_replica_devices_wraps_over_available():
    devs = replica_devices(3)
    assert len(devs) == 3 and all(d in jax.devices() for d in devs)
    with pytest.raises(ValueError):
        replica_devices(0)


# ---------------------------------------------------------------------------
# Routing parity (no chaos)
# ---------------------------------------------------------------------------

def test_fleet_parity_no_chaos(gemma):
    """Requests spread over 2 replicas all complete bit-identical to solo;
    placement balances rather than piling onto one replica."""
    cfg, params = gemma
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG)
    reqs = [_mk(cfg, i, 4 + i, 6, seed=i, greedy=(i % 2 == 0)) for i in range(4)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["completed"] == 4 and fleet.stats["shed"] == 0
    assert {r.replica for r in results} == {0, 1}  # both replicas served


# ---------------------------------------------------------------------------
# Crash failover
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lose_state", [False, True])
def test_crash_failover_parity(gemma, lose_state):
    """Killing a replica mid-decode re-routes its requests: with host state
    intact they resume teacher-forced from the recorded prefix
    (``failovers``), with state lost they restart from scratch
    (``restarts``) — the stream is identical either way."""
    cfg, params = gemma
    inj = FaultInjector()
    inj.crash(0, at_step=1, lose_state=lose_state)
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG,
                  injector=inj)
    reqs = [_mk(cfg, i, 5 + i, 8, seed=i) for i in range(4)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["crashes"] == 1 and inj.log[0]["kind"] == "crash"
    assert fleet.replicas[0].state == "dead"
    moved = fleet.stats["failovers"] + fleet.stats["restarts"]
    assert moved >= 1 and fleet.stats["retries"] == moved
    if lose_state:
        assert fleet.stats["failovers"] == 0  # nothing salvageable
    # exactly the re-routed requests record the extra placement attempt
    assert sum(r.attempts >= 2 for r in results) == moved


def test_dispatch_exception_is_a_crash(gemma):
    """A real exception out of ``Engine.step`` (not injected) fails the
    replica over instead of killing the fleet loop."""
    cfg, params = gemma
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG)
    boom = {"armed": True}
    orig = fleet.replicas[0].engine.step

    def bad_step(now):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("device dispatch failed")
        return orig(now)

    fleet.replicas[0].engine.step = bad_step
    reqs = [_mk(cfg, i, 5, 6, seed=40 + i) for i in range(3)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["crashes"] == 1
    assert fleet.replicas[0].state == "dead"


def test_all_replicas_dead_raises(gemma):
    cfg, params = gemma
    inj = FaultInjector()
    inj.crash(0, at_step=0)
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=1, hedge=False), ECFG,
                  injector=inj)
    with pytest.raises(RuntimeError, match="every replica"):
        fleet.run([_mk(cfg, 0, 5, 6)])


# ---------------------------------------------------------------------------
# Stalls + hedged dispatch
# ---------------------------------------------------------------------------

def test_stall_triggers_hedge_first_finisher_wins(gemma):
    """A stalled replica's in-flight requests are duplicated onto a healthy
    one; the duplicate finishes first, the stalled copy is cancelled, and
    the adopted stream is still exact."""
    cfg, params = gemma
    inj = FaultInjector()
    inj.stall(1, at_step=1, duration_s=30.0)
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=2, hedge=True, hedge_stall_s=0.1), ECFG,
        injector=inj,
    )
    reqs = [_mk(cfg, i, 4 + i, 6, seed=10 + i) for i in range(4)]
    t0 = time.perf_counter()
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["stalls"] == 1 and fleet.stats["hedges"] >= 1
    assert fleet.stats["cancels"] >= 1  # the losing copies were cancelled
    assert any(r.hedged for r in results)
    # first finisher wins: the adopted copies ran on the healthy replica,
    # and the trace never waited out the 30s stall
    assert all(r.replica == 0 for r in results if r.hedged)
    assert time.perf_counter() - t0 < 25.0


def test_slow_replica_accumulates_straggler_marks(gemma):
    """slow-by-factor chaos inflates the replica's reported step wall; the
    per-replica EWMA marks it and the mark count feeds placement cost."""
    cfg, params = gemma
    inj = FaultInjector()
    inj.slow(0, at_step=3, factor=1e5, steps=8)
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=2, hedge=True, hedge_after_marks=2,
                    hedge_stall_s=30.0), ECFG,
        injector=inj,
    )
    reqs = [_mk(cfg, i, 5, 16, seed=20 + i) for i in range(4)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["slows"] == 1
    assert len(fleet.replicas[0].straggler.events) >= 1


# ---------------------------------------------------------------------------
# Deadlines, shedding, degraded mode
# ---------------------------------------------------------------------------

def test_deadline_timeout_returns_partial_prefix(gemma):
    """A request that cannot finish inside its deadline retires as
    ``"timeout"`` with whatever tokens it emitted — a strict prefix of the
    solo stream — while its neighbours complete normally."""
    cfg, params = gemma
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=1, hedge=False), ECFG)
    slow = _mk(cfg, 0, 5, 40, seed=0, deadline_s=0.05)
    fine = _mk(cfg, 1, 5, 6, seed=1)
    res = fleet.run([slow, fine])
    assert res[0].status == "timeout"
    assert res[0].tokens == _solo(cfg, params, slow)[: len(res[0].tokens)]
    assert res[1].status == "ok" and res[1].tokens == _solo(cfg, params, fine)
    assert fleet.stats["timeouts"] == 1


def test_default_deadline_applies_to_undated_requests(gemma):
    cfg, params = gemma
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=1, hedge=False, default_deadline_s=0.05), ECFG,
    )
    res = fleet.run([_mk(cfg, 0, 5, 64 - 5, seed=0)])
    assert res[0].status == "timeout"
    assert fleet.requests[0].deadline_s == 0.05


def test_bounded_queue_sheds_and_degrades(gemma):
    """Backlog beyond ``max_queue`` is shed (recorded, never queued);
    between ``degrade_backlog`` and the cap new requests get their
    ``max_new_tokens`` clamped — and the clamped streams are still exact."""
    cfg, params = gemma
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=1, max_queue=3, degrade_backlog=2,
                    degrade_cap=2, hedge=False), ECFG,
    )
    reqs = [_mk(cfg, i, 4, 8, seed=30 + i) for i in range(6)]
    results = fleet.run(reqs)
    shed = [r for r in results if r.status == "shed"]
    ok = [r for r in results if r.status == "ok"]
    assert len(shed) == fleet.stats["shed"] >= 1
    assert fleet.stats["degraded"] >= 1
    assert all(r.tokens == [] and r.replica is None for r in shed)
    for r in ok:
        eff = fleet.requests[r.rid]
        assert r.tokens == _solo(cfg, params, eff), f"rid {r.rid}"
    clamped = [r for r in ok if fleet.requests[r.rid].max_new_tokens == 2]
    assert clamped, "degraded mode never clamped anything"


# ---------------------------------------------------------------------------
# Lifecycle: kill / drain / restore, health probes
# ---------------------------------------------------------------------------

def test_kill_drain_restore_lifecycle(gemma):
    """Operator lifecycle mid-trace: kill fails work over, drain migrates
    the waiting line and parks when empty, restore brings a dead replica
    back — all streams stay exact throughout."""
    cfg, params = gemma
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=3, hedge=False), ECFG)
    reqs = [_mk(cfg, i, 4 + i, 10, seed=20 + i) for i in range(6)]
    for r in reqs:
        fleet.submit(r)
    t0, cycle = time.perf_counter(), 0
    while not all(q.rid in fleet.results for q in reqs):
        now = time.perf_counter() - t0
        cycle += 1
        if cycle == 2:
            fleet.kill(1, now)
        if cycle == 3:
            fleet.drain(2, now)
        if cycle == 5:
            fleet.restore(1, now)
        fleet.step(now)
        assert cycle < 10_000
    results = [fleet.results[q.rid] for q in reqs]
    _assert_parity(cfg, params, fleet, reqs, results)
    s = fleet.stats
    assert s["kills"] == 1 and s["drains"] == 1 and s["restores"] == 1
    assert fleet.replicas[1].state == "live"
    assert fleet.replicas[2].state in ("draining", "down")


def test_restore_undrains_without_losing_work(gemma):
    cfg, params = gemma
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=1, hedge=False), ECFG)
    req = _mk(cfg, 0, 5, 6, seed=3)
    fleet.submit(req)
    fleet.step(0.0)
    fleet.replicas[0].state = "draining"
    fleet.restore(0)  # un-drain: same engine, in-flight slot intact
    assert fleet.replicas[0].state == "live"
    t0 = time.perf_counter()
    while 0 not in fleet.results:
        fleet.step(time.perf_counter() - t0)
    assert fleet.results[0].tokens == _solo(cfg, params, req)


def test_corrupt_probe_kills_healthy_replica_fleet_recovers(gemma):
    """corrupt-health-probe chaos: the probe lies, the fleet kills a
    perfectly healthy replica — and the failover path still completes every
    stream exactly."""
    cfg, params = gemma
    batch = {"tokens": jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(9), (1, 8), 0, cfg.vocab_size))}
    monitor = HealthMonitor(cfg, params, batch)
    inj = FaultInjector()
    inj.corrupt_probe(0, at_step=1)
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=2, hedge=False, health_every=1), ECFG,
        monitor=monitor, injector=inj,
    )
    reqs = [_mk(cfg, i, 5, 8, seed=50 + i) for i in range(4)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["probe_failures"] >= 1
    assert fleet.replicas[0].state == "dead"
    assert fleet.stats["probes"] >= 2  # healthy replicas kept probing clean


def test_transient_probe_failure_needs_consecutive_breaches(gemma):
    """Regression: with ``consecutive_breaches=2`` a single corrupted health
    probe is treated as transient — the replica records the breach but stays
    live, and the next clean probe resets the streak."""
    cfg, params = gemma
    batch = {"tokens": jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(9), (1, 8), 0, cfg.vocab_size))}
    monitor = HealthMonitor(cfg, params, batch,
                            HealthConfig(consecutive_breaches=2))
    inj = FaultInjector()
    inj.corrupt_probe(0, at_step=1, probes=1)
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=2, hedge=False, health_every=1), ECFG,
        monitor=monitor, injector=inj,
    )
    reqs = [_mk(cfg, i, 5, 8, seed=50 + i) for i in range(4)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["probe_failures"] == 1
    assert fleet.stats["kills"] == 0
    assert fleet.replicas[0].state == "live"  # survived the transient
    assert fleet.replicas[0].probe_breaches <= 1
    fleet._check_health(fleet._now + 1.0)  # one clean probe resets the streak
    assert fleet.replicas[0].probe_breaches == 0
    assert fleet.replicas[0].state == "live"


def test_storm_chaos_hits_integrity_pool_and_scrub_recovers(gemma):
    """Mid-trace fault-storm chaos lands on the replica's integrity-enabled
    pool; token streams are untouched (chaos never changes tokens) and the
    scrub/repair loop restores a bit-exact pool read."""
    from repro.core.integrity import IntegrityConfig
    from repro.core.planner import CrossbarSpec, PlannerConfig, _analyze_tensor_pool
    from repro.core.pool import CrossbarPool

    cfg, params = gemma
    spec = CrossbarSpec(rows=64, cols=8)
    pool = CrossbarPool(spec, 4, leveling="lpt")
    mgr = pool.enable_integrity(IntegrityConfig(spare_cols=2))
    w = jax.random.normal(jax.random.PRNGKey(0), (40, 20)) * 0.05
    _analyze_tensor_pool(w, spec, PlannerConfig(p_stuck=1.0, crossbars=4),
                         jax.random.PRNGKey(1), pool, name="t0")
    inj = FaultInjector()
    inj.storm(0, at_step=1, corrupt=5e-3, stuck=1e-3)
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG,
                  pools=[pool, None], injector=inj)
    reqs = [_mk(cfg, i, 5, 6, seed=60 + i) for i in range(3)]
    results = fleet.run(reqs)
    _assert_parity(cfg, params, fleet, reqs, results)
    assert fleet.stats["storms"] == 1 and inj.log[0]["kind"] == "storm"
    assert not mgr.verify_all()  # the storm really corrupted the pool
    rep = mgr.scrub_until_clean()
    assert rep.detections > 0 and mgr.verify_all() and mgr.clean


def test_mid_repair_replica_routed_around(gemma):
    """A replica whose scrubber holds pending (detected, budget-deferred)
    faults is excluded from placement while a healthy peer exists."""
    from repro.core.integrity import IntegrityConfig, tile_checksums
    from repro.core.planner import CrossbarSpec, PlannerConfig, _analyze_tensor_pool
    from repro.core.pool import CrossbarPool

    cfg, params = gemma
    spec = CrossbarSpec(rows=64, cols=8)
    pool = CrossbarPool(spec, 4, leveling="lpt")
    mgr = pool.enable_integrity(IntegrityConfig(spare_cols=4, repair_budget=1))
    w = jax.random.normal(jax.random.PRNGKey(0), (40, 20)) * 0.05
    _analyze_tensor_pool(w, spec, PlannerConfig(p_stuck=1.0, crossbars=4),
                         jax.random.PRNGKey(1), pool, name="t0")
    rec = mgr.tensors["t0"]
    for c in (0, 2):  # two hard faults; budget=1 defers the second repair
        rec.stuck1[0, 0, c] |= 0x80
        for arr in (rec.expected, rec.reference, rec.stored):
            arr[0, 0, c] &= 0x7F
    rec.checksums[0] = tile_checksums(rec.expected[0:1], mgr.cfg.tile_bytes)[0]
    if rec.parity is not None:
        rec.parity[0] = np.bitwise_xor.reduce(rec.expected[0], axis=1)
    mgr.scrub_round()
    assert mgr.pending_faults() > 0
    fleet = Fleet(cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG,
                  pools=[pool, None])
    assert fleet.replicas[0].mid_repair()
    # pending faults price into the score AND exclude the replica outright
    assert fleet.replicas[0].score(fleet.fcfg) >= fleet.fcfg.w_scrub
    req = _mk(cfg, 0, 5, 4, seed=7)
    res = fleet.run([req])
    assert res[0].replica == 1
    assert res[0].tokens == _solo(cfg, params, req)
    # once the scrubber converges the replica is placeable again
    mgr.scrub_until_clean()
    assert not fleet.replicas[0].mid_repair()


# ---------------------------------------------------------------------------
# Placement scoring
# ---------------------------------------------------------------------------

def test_placement_prefers_unworn_unfaulted_replica(gemma):
    """Wear/fault-aware placement: a replica whose pool is nearly exhausted
    (finite endurance horizon) and fault-ridden scores worse than a pristine
    one, so single requests route to the healthy replica."""
    from repro.core import nonideal
    from repro.core.planner import CrossbarSpec
    from repro.core.pool import CrossbarPool

    cfg, params = gemma
    worn = CrossbarPool(CrossbarSpec(rows=64, cols=8), 4)
    worn.wear[:] = 10**7  # deep into the endurance budget
    worn.inject_faults(nonideal.FaultModel(stuck0=0.02, stuck1=0.02),
                       jax.random.PRNGKey(0))
    fresh = CrossbarPool(CrossbarSpec(rows=64, cols=8), 4)
    fleet = Fleet(
        cfg, params, FleetConfig(n_replicas=2, hedge=False), ECFG,
        pools=[worn, fresh],
    )
    assert fleet.replicas[0].score(fleet.fcfg) > fleet.replicas[1].score(fleet.fcfg)
    req = _mk(cfg, 0, 5, 4, seed=7)
    res = fleet.run([req])
    assert res[0].replica == 1  # routed away from the worn pool
    assert res[0].tokens == _solo(cfg, params, req)


def test_pools_length_mismatch_rejected(gemma):
    cfg, params = gemma
    with pytest.raises(ValueError, match="one entry per replica"):
        Fleet(cfg, params, FleetConfig(n_replicas=2), ECFG, pools=[None])


# ---------------------------------------------------------------------------
# Retry budget
# ---------------------------------------------------------------------------

def test_retry_budget_exhaustion_sheds(gemma):
    """A request that loses its replica more times than the retry budget is
    shed rather than bounced forever."""
    cfg, params = gemma
    fleet = Fleet(
        cfg, params,
        FleetConfig(n_replicas=2, hedge=False,
                    retry=FaultPolicy(max_retries=1, backoff_s=0.0)),
        ECFG,
    )
    req = _mk(cfg, 0, 5, 48, seed=0)
    fleet.submit(req)
    fleet.step(0.0)
    fleet.kill(0, 0.1)  # placement 1 lost
    fleet.step(0.2)     # re-placed on replica 1 (placement 2 = max)
    fleet.kill(1, 0.3)  # placement 2 lost -> budget spent -> shed
    fleet.step(0.4)
    assert fleet.results[0].status == "shed"
    assert fleet.stats["shed"] == 1
