"""Unit + property tests for the reprogramming cost model (Eq. 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import bitslice, cost


def _planes(seed: int, s: int, rows: int, cols: int):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2, (s, rows, cols)), jnp.bool_)


def test_pair_transitions_identity_and_symmetry():
    a, b = _planes(0, 4, 16, 8), _planes(1, 4, 16, 8)
    assert int(jnp.sum(cost.pair_transitions(a, a))) == 0
    np.testing.assert_array_equal(cost.pair_transitions(a, b), cost.pair_transitions(b, a))


@given(seed=st.integers(0, 100))
def test_hamming_triangle_inequality(seed):
    a, b, c = (_planes(seed + i, 3, 8, 6) for i in range(3))
    ab = cost.pair_transitions(a, b)
    bc = cost.pair_transitions(b, c)
    ac = cost.pair_transitions(a, c)
    assert bool(jnp.all(ac <= ab + bc))


def test_packed_matches_bool_path():
    a, b = _planes(2, 6, 40, 10), _planes(3, 6, 40, 10)
    pa, pb = bitslice.pack_rows(a), bitslice.pack_rows(b)
    np.testing.assert_array_equal(
        cost.pair_transitions_packed(pa, pb), cost.pair_transitions(a, b)
    )


def test_chain_equals_sum_of_consecutive():
    planes = _planes(4, 10, 16, 8)
    order = jnp.asarray(np.random.default_rng(0).permutation(10), jnp.int32)
    total = int(cost.chain_transitions(planes, order))
    steps = cost.consecutive_costs(planes, order)
    assert total == int(jnp.sum(steps))
    # without initial program
    total_ni = int(cost.chain_transitions(planes, order, include_initial=False))
    assert total_ni == int(jnp.sum(steps[1:]))


def test_chain_per_column_sums_to_total():
    planes = _planes(5, 8, 16, 8)
    per_col = cost.chain_transitions(planes, per_column=True)
    total = cost.chain_transitions(planes)
    assert int(jnp.sum(per_col)) == int(total)


def test_low_order_columns_carry_transition_mass(key):
    """§IV observation: for bell-shaped weights the transition mass under a
    sorted order concentrates in low-order columns (adjacent sorted sections
    differ by small q deltas, so flips ride the low bits + short carries),
    and the LSB's active fraction is ~Bernoulli(0.5)."""
    w = jax.random.normal(key, (128 * 64,)) * 0.02
    qt = bitslice.quantize(w, 10)
    order = jnp.argsort(jnp.abs(w))
    planes = bitslice.bitplanes(qt.q[order].reshape(64, 128), 10)
    frac = cost.transition_fraction_per_column(planes)
    # the low half of the columns carries the overwhelming share
    assert float(jnp.sum(frac[:5])) > 0.75
    # monotone decay in the high-order half
    assert bool(jnp.all(frac[5:-1] >= frac[6:]))
    # active fraction in the LSB is ~0.5 (the uniformity §IV leverages)
    active = cost.active_fraction_per_column(planes)
    assert 0.4 <= float(active[0]) <= 0.6
