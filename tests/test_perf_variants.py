"""Regression tests for the §Perf variants: banded SWA and sharded MoE.

The sharded-MoE parity check needs >1 device, and jax locks the host device
count at first init, so it runs in a subprocess with its own XLA_FLAGS —
the same isolation rule the dry-run uses.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attention,
    banded_swa_attention,
    blockwise_attention,
    set_attention_impl,
)


@pytest.mark.parametrize(
    "sq,sk,w,bq,qo",
    [(256, 256, 64, 64, 0), (200, 200, 48, 64, 0), (128, 144, 32, 32, 16), (100, 228, 32, 48, 128)],
)
def test_banded_swa_matches_blockwise(sq, sk, w, bq, qo):
    ks = jax.random.split(jax.random.PRNGKey(sq + sk), 3)
    q = jax.random.normal(ks[0], (2, 4, sq, 16))
    k = jax.random.normal(ks[1], (2, 2, sk, 16))
    v = jax.random.normal(ks[2], (2, 2, sk, 16))
    ref = blockwise_attention(q, k, v, kind="swa", window=w, q_offset=qo, block_k=32)
    got = banded_swa_attention(q, k, v, window=w, q_offset=qo, block_q=bq)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_attention_dispatch_flag(key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 16))
    k = jax.random.normal(ks[1], (1, 2, 64, 16))
    v = jax.random.normal(ks[2], (1, 2, 64, 16))
    base = attention(q, k, v, kind="swa", window=16)
    try:
        set_attention_impl(swa_banded=True, swa_block_q=32)
        banded = attention(q, k, v, kind="swa", window=16)
    finally:
        set_attention_impl(swa_banded=False)
    np.testing.assert_allclose(banded, base, rtol=2e-5, atol=2e-5)


def test_hymba_forward_same_with_banded(key):
    """Model-level parity: hymba forward is unchanged by the banded impl."""
    from repro.configs import get_arch
    from repro.models import api

    cfg = get_arch("hymba-1.5b", reduced=True)
    params = api.init(key, cfg)
    batch = api.make_batch(cfg, key, 2, 16)
    ref, _ = api.forward(params, cfg, batch)
    try:
        set_attention_impl(swa_banded=True, swa_block_q=8)
        got, _ = api.forward(params, cfg, batch)
    finally:
        set_attention_impl(swa_banded=False)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)  # bf16 PV path


_SHARDED_MOE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import moe as moe_lib

    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = moe_lib.init_moe_mlp(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y_ref, _ = jax.jit(lambda p, x: moe_lib.moe_mlp(p, cfg, x))(p, x)

    # EP path (8 experts % 2 == 0) on a (pod, data, model) mesh
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    moe_lib.set_moe_distribution(mesh)
    with mesh:
        y_ep, _ = jax.jit(lambda p, x: moe_lib.moe_mlp(p, cfg, x))(p, x)
    moe_lib.set_moe_distribution(None)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    assert err < 1e-4, f"EP parity {err}"

    # TP fallback path (6 experts % 4 != 0)
    cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, n_routed=6, capacity_factor=8.0))
    p2 = moe_lib.init_moe_mlp(key, cfg2)
    y_ref2, _ = jax.jit(lambda p, x: moe_lib.moe_mlp(p, cfg2, x))(p2, x)
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    moe_lib.set_moe_distribution(mesh2)
    with mesh2:
        y_tp, _ = jax.jit(lambda p, x: moe_lib.moe_mlp(p, cfg2, x))(p2, x)
    moe_lib.set_moe_distribution(None)
    err = float(jnp.max(jnp.abs(y_ref2 - y_tp)))
    assert err < 1e-4, f"TP parity {err}"
    print("SHARDED_MOE_OK")
    """
)


@pytest.mark.slow  # spawns a fresh 8-device interpreter: minutes of wall clock
def test_sharded_moe_parity_subprocess():
    env = dict(os.environ, PYTHONPATH="src", XLA_FLAGS="")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_MOE_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert "SHARDED_MOE_OK" in out.stdout, out.stdout + out.stderr


def test_expert_padding_rows_unused(key):
    """Padded expert rows (n_alloc > n_routed) never receive tokens: zeroing
    them does not change the output."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models import moe as moe_lib

    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, pad_experts_to=12, capacity_factor=8.0)
    )
    p = moe_lib.init_moe_mlp(key, cfg)
    assert p["wi_gate"].shape[0] == 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y1, _ = moe_lib.moe_mlp(p, cfg, x)
    p2 = dict(p)
    for name in ("wi_gate", "wi_up", "wo"):
        p2[name] = p[name].at[cfg.moe.n_routed :].set(0.0)
    y2, _ = moe_lib.moe_mlp(p2, cfg, x)
    np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)
